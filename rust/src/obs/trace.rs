//! Span tracing: per-thread bounded event buffers drained into a Chrome
//! trace-event / Perfetto-compatible JSON document.
//!
//! Recording is lock-free on the hot path: each thread appends into a
//! thread-local `Vec` (the ring) and only takes the global sink lock when
//! the ring fills or the thread exits (a `Drop` guard on the thread-local
//! flushes the tail). Timestamps are nanoseconds since a process-wide
//! epoch pinned when tracing is first enabled.
//!
//! Event phases follow the Chrome trace-event format:
//! `B`/`E` duration spans and `i` instants on the recording thread's tid,
//! `b`/`n`/`e` async spans keyed by `id` for lifecycles that migrate
//! across threads (job submit→complete), and `M` metadata (thread names).

use std::borrow::Cow;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Per-thread ring capacity: a full ring is flushed to the sink in one
/// lock acquisition, so the lock rate is 1/RING_CAP of the event rate.
const RING_CAP: usize = 4096;

/// Global backstop: events past this cap are counted in [`dropped`]
/// instead of buffered, so a runaway trace cannot exhaust memory.
const MAX_EVENTS: usize = 1 << 20;

/// One trace event. `ph` is the Chrome trace-event phase character.
#[derive(Clone, Debug)]
pub struct Event {
    pub ph: &'static str,
    pub name: Cow<'static, str>,
    pub cat: &'static str,
    /// Nanoseconds since the trace epoch.
    pub ts_ns: u64,
    /// Recording thread, assigned in first-touch order (1 = first thread
    /// that recorded).
    pub tid: u32,
    /// Async-span correlation id (`b`/`n`/`e` phases only).
    pub id: Option<u64>,
    pub args: Vec<(&'static str, ArgVal)>,
}

/// Small typed argument payload attached to an event.
#[derive(Clone, Debug)]
pub enum ArgVal {
    I64(i64),
    F64(f64),
    Str(String),
}

impl From<&ArgVal> for Json {
    fn from(v: &ArgVal) -> Json {
        match v {
            ArgVal::I64(x) => Json::Int(*x),
            ArgVal::F64(x) => Json::Float(*x),
            ArgVal::Str(s) => Json::Str(s.clone()),
        }
    }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_ASYNC_ID: AtomicU64 = AtomicU64::new(1);

struct LocalRing {
    tid: u32,
    events: Vec<Event>,
}

impl LocalRing {
    fn new() -> LocalRing {
        LocalRing {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed) as u32,
            events: Vec::new(),
        }
    }
}

impl Drop for LocalRing {
    fn drop(&mut self) {
        // Thread exit: flush the tail so worker events survive the join.
        flush_into_sink(&mut self.events);
    }
}

thread_local! {
    static LOCAL: RefCell<LocalRing> = RefCell::new(LocalRing::new());
}

fn flush_into_sink(events: &mut Vec<Event>) {
    if events.is_empty() {
        return;
    }
    let mut sink = SINK.lock().unwrap();
    let room = MAX_EVENTS.saturating_sub(sink.len());
    if events.len() > room {
        DROPPED.fetch_add((events.len() - room) as u64, Ordering::Relaxed);
        events.truncate(room);
    }
    sink.append(events);
}

/// Pin the trace epoch (idempotent). Called by [`crate::obs::set_trace`].
pub fn init_epoch() {
    EPOCH.get_or_init(Instant::now);
}

/// Nanoseconds since the trace epoch.
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// A fresh process-unique id for an async (`b`/`n`/`e`) span.
pub fn next_async_id() -> u64 {
    NEXT_ASYNC_ID.fetch_add(1, Ordering::Relaxed)
}

/// Events discarded by the [`MAX_EVENTS`] backstop.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Append one event to the calling thread's ring (stamping its tid),
/// flushing to the global sink when the ring fills.
pub fn record(mut ev: Event) {
    LOCAL.with(|l| {
        let mut ring = l.borrow_mut();
        ev.tid = ring.tid;
        ring.events.push(ev);
        if ring.events.len() >= RING_CAP {
            flush_into_sink(&mut ring.events);
        }
    });
}

fn event(ph: &'static str, name: Cow<'static, str>, cat: &'static str) -> Event {
    Event { ph, name, cat, ts_ns: now_ns(), tid: 0, id: None, args: Vec::new() }
}

/// Open a duration span (`B`). Prefer [`crate::obs::Span`], which pairs
/// the close automatically.
pub fn begin(name: impl Into<Cow<'static, str>>, cat: &'static str) {
    if !crate::obs::trace_enabled() {
        return;
    }
    record(event("B", name.into(), cat));
}

/// Open a duration span (`B`) carrying a typed-arg payload.
pub fn begin_args(
    name: impl Into<Cow<'static, str>>,
    cat: &'static str,
    args: Vec<(&'static str, ArgVal)>,
) {
    if !crate::obs::trace_enabled() {
        return;
    }
    let mut ev = event("B", name.into(), cat);
    ev.args = args;
    record(ev);
}

/// Close the innermost duration span with this name (`E`).
pub fn end(name: impl Into<Cow<'static, str>>, cat: &'static str) {
    if !crate::obs::trace_enabled() {
        return;
    }
    record(event("E", name.into(), cat));
}

/// A zero-duration instant (`i`) on the calling thread.
pub fn instant(
    name: impl Into<Cow<'static, str>>,
    cat: &'static str,
    args: Vec<(&'static str, ArgVal)>,
) {
    if !crate::obs::trace_enabled() {
        return;
    }
    let mut ev = event("i", name.into(), cat);
    ev.args = args;
    record(ev);
}

fn async_event(
    ph: &'static str,
    name: impl Into<Cow<'static, str>>,
    cat: &'static str,
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
) {
    if !crate::obs::trace_enabled() {
        return;
    }
    let mut ev = event(ph, name.into(), cat);
    ev.id = Some(id);
    ev.args = args;
    record(ev);
}

/// Open an async span (`b`): a lifecycle that may end on another thread.
pub fn async_begin(
    name: impl Into<Cow<'static, str>>,
    cat: &'static str,
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
) {
    async_event("b", name, cat, id, args);
}

/// A milestone (`n`) inside an async span.
pub fn async_instant(
    name: impl Into<Cow<'static, str>>,
    cat: &'static str,
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
) {
    async_event("n", name, cat, id, args);
}

/// Close an async span (`e`).
pub fn async_end(
    name: impl Into<Cow<'static, str>>,
    cat: &'static str,
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
) {
    async_event("e", name, cat, id, args);
}

/// Record the calling thread's display name (an `M` metadata event).
pub fn set_thread_name(name: &str) {
    if !crate::obs::trace_enabled() {
        return;
    }
    let mut ev = event("M", Cow::Borrowed("thread_name"), "__metadata");
    ev.args = vec![("name", ArgVal::Str(name.to_string()))];
    record(ev);
}

/// Flush the calling thread's ring into the global sink.
pub fn flush_thread() {
    LOCAL.with(|l| flush_into_sink(&mut l.borrow_mut().events));
}

/// Flush the calling thread and take every buffered event, sorted by
/// timestamp (stable, so per-thread order is preserved). Worker threads
/// must be joined first — their tails flush via the thread-exit guard.
pub fn drain() -> Vec<Event> {
    flush_thread();
    let mut events = std::mem::take(&mut *SINK.lock().unwrap());
    events.sort_by_key(|e| e.ts_ns);
    events
}

/// Drop all buffered events and the drop counter (test isolation; the
/// epoch and tid counters are process-lifetime and stay).
pub fn reset() {
    LOCAL.with(|l| l.borrow_mut().events.clear());
    SINK.lock().unwrap().clear();
    DROPPED.store(0, Ordering::Relaxed);
}

/// Serialize events as a Chrome trace-event document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}` with `ts` in
/// microseconds — directly loadable in Perfetto / `chrome://tracing`.
pub fn export_json(events: &[Event]) -> Json {
    let rows: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut row = Json::object();
            row.set("name", e.name.as_ref())
                .set("cat", e.cat)
                .set("ph", e.ph)
                .set("ts", e.ts_ns as f64 / 1000.0)
                .set("pid", 1i64)
                .set("tid", e.tid as i64);
            if let Some(id) = e.id {
                row.set("id", id as i64);
            }
            if !e.args.is_empty() {
                let mut args = Json::object();
                for (k, v) in &e.args {
                    args.set(k, Json::from(v));
                }
                row.set("args", args);
            }
            row
        })
        .collect();
    let mut doc = Json::object();
    doc.set("traceEvents", Json::Array(rows)).set("displayTimeUnit", "ms");
    doc
}

/// Drain and export in one step (the `--trace <file>` path).
pub fn export_current() -> Json {
    let events = drain();
    export_json(&events)
}

/// Aggregates computed from a trace document by `bombyx trace summarize`.
#[derive(Debug, Default)]
pub struct TraceSummary {
    /// Per span name: (count, total_ms, max_ms), hottest first.
    pub spans: Vec<(String, u64, f64, f64)>,
    /// Per async job span: (name, id, latency_ms, milestones in order).
    pub jobs: Vec<(String, i64, f64, Vec<String>)>,
    /// `B` events with no matching `E` (or vice versa) — 0 on a clean
    /// trace.
    pub unbalanced: u64,
}

/// Fold a parsed Chrome trace-event document into per-span and per-job
/// aggregates. Duration spans are matched `B`/`E` per tid (LIFO); async
/// spans are matched `b`/`e` per id.
pub fn summarize(doc: &Json) -> Result<TraceSummary, String> {
    use std::collections::BTreeMap;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_array())
        .ok_or("missing traceEvents array")?;
    // (tid -> stack of (name, ts)); span name -> (count, total, max).
    let mut stacks: BTreeMap<i64, Vec<(String, f64)>> = BTreeMap::new();
    let mut spans: BTreeMap<String, (u64, f64, f64)> = BTreeMap::new();
    // async id -> (name, begin ts, milestones).
    let mut open_jobs: BTreeMap<i64, (String, f64, Vec<String>)> = BTreeMap::new();
    let mut jobs = Vec::new();
    let mut unbalanced = 0u64;
    for ev in events {
        let ph = ev.get("ph").and_then(|v| v.as_str()).ok_or("event missing ph")?;
        let name =
            ev.get("name").and_then(|v| v.as_str()).ok_or("event missing name")?.to_string();
        let ts = match ev.get("ts") {
            Some(Json::Float(v)) => *v,
            Some(Json::Int(v)) => *v as f64,
            _ => return Err(format!("event `{name}` missing numeric ts")),
        };
        let tid = ev.get("tid").and_then(|v| v.as_i64()).unwrap_or(0);
        let id = ev.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
        match ph {
            "B" => stacks.entry(tid).or_default().push((name, ts)),
            "E" => match stacks.entry(tid).or_default().pop() {
                Some((open, t0)) if open == name => {
                    let ms = (ts - t0) / 1000.0;
                    let e = spans.entry(open).or_insert((0, 0.0, 0.0));
                    e.0 += 1;
                    e.1 += ms;
                    e.2 = e.2.max(ms);
                }
                _ => unbalanced += 1,
            },
            "b" => {
                open_jobs.insert(id, (name, ts, Vec::new()));
            }
            "n" => {
                if let Some(j) = open_jobs.get_mut(&id) {
                    j.2.push(name);
                }
            }
            "e" => match open_jobs.remove(&id) {
                Some((jname, t0, marks)) => {
                    jobs.push((jname, id, (ts - t0) / 1000.0, marks));
                }
                None => unbalanced += 1,
            },
            _ => {}
        }
    }
    unbalanced += stacks.values().map(|s| s.len() as u64).sum::<u64>();
    unbalanced += open_jobs.len() as u64;
    let mut spans: Vec<(String, u64, f64, f64)> =
        spans.into_iter().map(|(n, (c, t, m))| (n, c, t, m)).collect();
    spans.sort_by(|a, b| b.2.total_cmp(&a.2));
    Ok(TraceSummary { spans, jobs, unbalanced })
}
