//! PJRT CPU client wrapper: HLO text → compiled executable, executed with
//! concrete literals.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids. Lowering uses `return_tuple=True`, so results unwrap as
//! tuples on this side.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A loaded set of AOT executables, keyed by artifact stem
/// (e.g. `relax_b256_f16`).
pub struct XlaRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and eagerly compile every `*.hlo.txt` in
    /// `dir`.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let mut rt = XlaRuntime { client, executables: HashMap::new(), dir: dir.clone() };
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("artifacts directory {dir:?} (run `make artifacts`)"))?;
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("txt") {
                continue;
            }
            let stem = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or_default()
                .trim_end_matches(".hlo.txt")
                .to_string();
            rt.load_file(&stem, &path)?;
        }
        if rt.executables.is_empty() {
            anyhow::bail!("no *.hlo.txt artifacts found in {dir:?} (run `make artifacts`)");
        }
        Ok(rt)
    }

    fn load_file(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Execute by name; inputs are literals; returns the elements of the
    /// result tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable `{name}` (have: {:?})", self.names()))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute `{name}`: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of `{name}`: {e:?}"))?;
        out.to_tuple().map_err(|e| anyhow!("untuple result of `{name}`: {e:?}"))
    }
}

/// Build an f32 literal of the given 2-D shape.
pub fn literal_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

pub fn literal_f32_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<XlaRuntime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        XlaRuntime::load_dir(dir).ok()
    }

    #[test]
    fn loads_and_runs_relax_artifact() {
        let Some(rt) = artifacts() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        assert!(rt.has("relax_b64_f16"), "{:?}", rt.names());
        let f = crate::workloads::relax::F;
        let (w, b) = crate::workloads::relax::weights(1);
        let x = vec![0.5f32; 64 * f];
        let inputs = vec![
            literal_f32_2d(&x, 64, f).unwrap(),
            literal_f32_2d(&w, f, f).unwrap(),
            literal_f32_1d(&b),
        ];
        let out = rt.execute("relax_b64_f16", &inputs).unwrap();
        assert_eq!(out.len(), 2);
        let y = out[0].to_vec::<f32>().unwrap();
        let scores = out[1].to_vec::<i32>().unwrap();
        assert_eq!(y.len(), 64 * f);
        assert_eq!(scores.len(), 64);
        // Cross-check row 0 against the scalar reference.
        let (y_ref, score_ref) = crate::workloads::relax::relax_ref(&x[..f], &w, &b);
        for (a, e) in y[..f].iter().zip(&y_ref) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        assert!((scores[0] - (score_ref * 1000.0) as i32).abs() <= 2, "{} vs {}", scores[0], score_ref * 1000.0);
    }

    #[test]
    fn missing_executable_is_reported() {
        let Some(rt) = artifacts() else { return };
        match rt.execute("nope", &[]) {
            Err(err) => assert!(err.to_string().contains("no executable")),
            Ok(_) => panic!("expected an error for unknown executable"),
        }
    }
}
