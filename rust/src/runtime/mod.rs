//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the Rust hot path. Python is build-time only — after
//! `make artifacts` the binary is self-contained.

pub mod client;
pub mod relax;

pub use client::XlaRuntime;
pub use relax::{RelaxService, RelaxXla};
