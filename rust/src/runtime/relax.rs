//! The batched XLA "PE": executes `extern xla int relax(int n)` task
//! batches through the AOT-compiled Pallas datapath.
//!
//! The batcher plays the DAE *access* role (DESIGN.md
//! §Hardware-Adaptation): it gathers the feature rows of all ready tasks
//! into a contiguous `[B, F]` tile (padding partial batches with zero
//! rows), runs the executable once, scatters updated rows back to global
//! memory, and delivers each task's frontier score to its continuation.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::interp::Memory;
use crate::ir::cfg::{GlobalId, Module};
use crate::ir::expr::Value;
use crate::sim::SimXla;
use crate::workloads::relax::F;
use crate::ws::{SharedMemory, XlaSink};

use super::client::{literal_f32_1d, literal_f32_2d, XlaRuntime};

/// Batch variants compiled by `python/compile/aot.py`, ascending.
const VARIANTS: &[(usize, &str)] = &[(64, "relax_b64_f16"), (256, "relax_b256_f16")];

pub struct RelaxXla {
    runtime: XlaRuntime,
    w: Vec<f32>,
    b: Vec<f32>,
    feat_global: GlobalId,
    /// Calls recorded (batch sizes), for tests/benches.
    pub batches: Mutex<Vec<usize>>,
}

impl RelaxXla {
    pub fn new(runtime: XlaRuntime, module: &Module, weight_seed: u64) -> Result<RelaxXla> {
        for (_, name) in VARIANTS {
            if !runtime.has(name) {
                bail!("artifact `{name}` missing — run `make artifacts`");
            }
        }
        let (w, b) = crate::workloads::relax::weights(weight_seed);
        let feat_global = module
            .global_by_name("feat")
            .ok_or_else(|| anyhow!("relax workload needs a `feat` global"))?;
        Ok(RelaxXla { runtime, w, b, feat_global, batches: Mutex::new(Vec::new()) })
    }

    /// Pick the smallest variant that fits `n` rows.
    fn variant(n: usize) -> (usize, &'static str) {
        for &(cap, name) in VARIANTS {
            if n <= cap {
                return (cap, name);
            }
        }
        *VARIANTS.last().unwrap()
    }

    /// Core: gather rows → execute → scatter rows; returns milli-scores.
    fn run_batch(
        &self,
        node_ids: &[i64],
        load_row: &mut dyn FnMut(usize) -> Result<Vec<f32>>,
        store_row: &mut dyn FnMut(usize, &[f32]) -> Result<()>,
    ) -> Result<Vec<i64>> {
        let mut out = Vec::with_capacity(node_ids.len());
        let mut offset = 0;
        while offset < node_ids.len() {
            let chunk_len = (node_ids.len() - offset).min(VARIANTS.last().unwrap().0);
            let chunk = &node_ids[offset..offset + chunk_len];
            let (cap, name) = Self::variant(chunk.len());
            let mut x = vec![0f32; cap * F];
            for (i, &n) in chunk.iter().enumerate() {
                let row = load_row(n as usize)?;
                x[i * F..(i + 1) * F].copy_from_slice(&row);
            }
            let inputs = vec![
                literal_f32_2d(&x, cap, F)?,
                literal_f32_2d(&self.w, F, F)?,
                literal_f32_1d(&self.b),
            ];
            let result = self.runtime.execute(name, &inputs)?;
            let y = result[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("fetch y: {e:?}"))?;
            let scores = result[1]
                .to_vec::<i32>()
                .map_err(|e| anyhow!("fetch scores: {e:?}"))?;
            for (i, &n) in chunk.iter().enumerate() {
                store_row(n as usize, &y[i * F..(i + 1) * F])?;
                out.push(scores[i] as i64);
            }
            self.batches.lock().unwrap().push(chunk.len());
            offset += chunk_len;
        }
        Ok(out)
    }

    fn node_ids(batch: &[Vec<Value>]) -> Result<Vec<i64>> {
        batch
            .iter()
            .map(|args| {
                args.first()
                    .map(|v| v.as_i64())
                    .ok_or_else(|| anyhow!("relax task takes a node id"))
            })
            .collect()
    }
}

/// WS-runtime sink: the PJRT client is `!Send`, so a dedicated service
/// thread owns the [`XlaRuntime`]; workers gather/scatter feature rows on
/// their side and exchange dense tiles over channels. (This mirrors the
/// hardware: PEs talk to the blackbox systolic datapath over streams.)
pub struct RelaxService {
    req_tx: Mutex<std::sync::mpsc::Sender<TileReq>>,
    feat_global: GlobalId,
    pub batches: Mutex<Vec<usize>>,
}

struct TileReq {
    /// Dense [rows, F] gather of the batch's feature rows.
    x: Vec<f32>,
    rows: usize,
    resp: std::sync::mpsc::Sender<Result<(Vec<f32>, Vec<i32>)>>,
}

impl RelaxService {
    /// Spawn the service thread (loads artifacts inside the thread since
    /// the client is thread-bound). Blocks until the runtime is ready.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        module: &Module,
        weight_seed: u64,
    ) -> Result<RelaxService> {
        let feat_global = module
            .global_by_name("feat")
            .ok_or_else(|| anyhow!("relax workload needs a `feat` global"))?;
        let (req_tx, req_rx) = std::sync::mpsc::channel::<TileReq>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("relax-xla".into())
            .spawn(move || {
                let setup = (|| -> Result<(XlaRuntime, Vec<f32>, Vec<f32>)> {
                    let rt = XlaRuntime::load_dir(&artifacts_dir)?;
                    for (_, name) in VARIANTS {
                        if !rt.has(name) {
                            bail!("artifact `{name}` missing — run `make artifacts`");
                        }
                    }
                    let (w, b) = crate::workloads::relax::weights(weight_seed);
                    Ok((rt, w.to_vec(), b.to_vec()))
                })();
                let (rt, w, b) = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = req_rx.recv() {
                    let result = exec_tile(&rt, &w, &b, &req.x, req.rows);
                    let _ = req.resp.send(result);
                }
            })
            .map_err(|e| anyhow!("spawn relax-xla thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("relax-xla thread died during startup"))??;
        Ok(RelaxService {
            req_tx: Mutex::new(req_tx),
            feat_global,
            batches: Mutex::new(Vec::new()),
        })
    }

    fn call(&self, x: Vec<f32>, rows: usize) -> Result<(Vec<f32>, Vec<i32>)> {
        let (resp_tx, resp_rx) = std::sync::mpsc::channel();
        self.req_tx
            .lock()
            .unwrap()
            .send(TileReq { x, rows, resp: resp_tx })
            .map_err(|_| anyhow!("relax-xla service stopped"))?;
        resp_rx.recv().map_err(|_| anyhow!("relax-xla service dropped a request"))?
    }
}

/// Execute one dense tile (pads to the best variant).
fn exec_tile(
    rt: &XlaRuntime,
    w: &[f32],
    b: &[f32],
    x: &[f32],
    rows: usize,
) -> Result<(Vec<f32>, Vec<i32>)> {
    assert_eq!(x.len(), rows * F);
    let mut y_all = Vec::with_capacity(rows * F);
    let mut s_all = Vec::with_capacity(rows);
    let mut offset = 0;
    while offset < rows {
        let chunk = (rows - offset).min(VARIANTS.last().unwrap().0);
        let (cap, name) = RelaxXla::variant(chunk);
        let mut tile = vec![0f32; cap * F];
        tile[..chunk * F].copy_from_slice(&x[offset * F..(offset + chunk) * F]);
        let inputs = vec![
            literal_f32_2d(&tile, cap, F)?,
            literal_f32_2d(w, F, F)?,
            literal_f32_1d(b),
        ];
        let result = rt.execute(name, &inputs)?;
        let y = result[0].to_vec::<f32>().map_err(|e| anyhow!("fetch y: {e:?}"))?;
        let s = result[1].to_vec::<i32>().map_err(|e| anyhow!("fetch scores: {e:?}"))?;
        y_all.extend_from_slice(&y[..chunk * F]);
        s_all.extend_from_slice(&s[..chunk]);
        offset += chunk;
    }
    Ok((y_all, s_all))
}

impl XlaSink for RelaxService {
    fn exec_batch(
        &self,
        name: &str,
        batch: &[Vec<Value>],
        mem: &SharedMemory,
    ) -> Result<Vec<Value>> {
        if name != "relax" {
            bail!("RelaxService only implements `relax`, got `{name}`");
        }
        let ids = RelaxXla::node_ids(batch)?;
        let g = self.feat_global;
        // Gather.
        let mut x = vec![0f32; ids.len() * F];
        for (i, &n) in ids.iter().enumerate() {
            for j in 0..F {
                x[i * F + j] = mem.load(g, n * F as i64 + j as i64)?.as_f32();
            }
        }
        let (y, scores) = self.call(x, ids.len())?;
        // Scatter.
        for (i, &n) in ids.iter().enumerate() {
            for j in 0..F {
                mem.store(g, n * F as i64 + j as i64, Value::F32(y[i * F + j]))?;
            }
        }
        self.batches.lock().unwrap().push(ids.len());
        Ok(scores.into_iter().map(|s| Value::I64(s as i64)).collect())
    }

    fn preferred_batch(&self) -> usize {
        VARIANTS.last().unwrap().0
    }
}

/// Simulator datapath (sequential Memory).
impl SimXla for RelaxXla {
    fn exec_batch(
        &mut self,
        name: &str,
        batch: &[Vec<Value>],
        memory: &mut Memory,
    ) -> Result<Vec<Value>> {
        if name != "relax" {
            bail!("RelaxXla only implements `relax`, got `{name}`");
        }
        let ids = Self::node_ids(batch)?;
        let g = self.feat_global;
        // Split borrows: copy rows in/out through locals.
        let mut rows_in: Vec<Vec<f32>> = Vec::with_capacity(ids.len());
        for &n in &ids {
            let mut row = Vec::with_capacity(F);
            for j in 0..F {
                row.push(memory.load(g, n * F as i64 + j as i64)?.as_f32());
            }
            rows_in.push(row);
        }
        let mut idx = std::collections::HashMap::new();
        for (i, &n) in ids.iter().enumerate() {
            idx.insert(n as usize, i);
        }
        let scores = self.run_batch(
            &ids,
            &mut |n| Ok(rows_in[idx[&n]].clone()),
            &mut |n, row| {
                for (j, &v) in row.iter().enumerate() {
                    memory.store(g, (n * F + j) as i64, Value::F32(v))?;
                }
                Ok(())
            },
        )?;
        Ok(scores.into_iter().map(Value::I64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::relax;

    fn runtime() -> Option<XlaRuntime> {
        XlaRuntime::load_dir(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()
    }

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn service_matches_scalar_reference() {
        if runtime().is_none() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let svc = RelaxService::start(artifacts_dir(), m, 1).unwrap();

        // Scalar path.
        let (w, b) = relax::weights(1);
        let mut feat: Vec<f32> = (0..5 * F).map(|i| (i as f32 * 0.13).sin().abs()).collect();
        let mut scalar_scores = Vec::new();
        for n in 0..5i64 {
            let v = relax::scalar_relax(&[Value::I64(n)], &mut feat, &w, &b).unwrap();
            scalar_scores.push(v.as_i64());
        }

        // Batched path on a SharedMemory image.
        let mut mem = SharedMemory::new(m);
        let init: Vec<f32> = (0..5 * F).map(|i| (i as f32 * 0.13).sin().abs()).collect();
        mem.fill_f32(m.global_by_name("feat").unwrap(), &init);
        let batch: Vec<Vec<Value>> = (0..5i64).map(|n| vec![Value::I64(n)]).collect();
        let scores = XlaSink::exec_batch(&svc, "relax", &batch, &mem).unwrap();

        for (s, r) in scores.iter().zip(&scalar_scores) {
            assert!(
                (s.as_i64() - r).abs() <= 2,
                "score mismatch: xla={} scalar={r}",
                s.as_i64()
            );
        }
        let feat_xla = mem.dump_f32(m.global_by_name("feat").unwrap());
        for (a, e) in feat_xla.iter().zip(&feat) {
            assert!((a - e).abs() < 1e-4, "feature mismatch: {a} vs {e}");
        }
    }

    #[test]
    fn oversized_batches_are_chunked() {
        if runtime().is_none() {
            return;
        }
        let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let svc = RelaxService::start(artifacts_dir(), m, 1).unwrap();
        let n = 300usize;
        let mut mem = SharedMemory::new(m);
        mem.fill_f32(m.global_by_name("feat").unwrap(), &vec![0.25f32; n * F]);
        let batch: Vec<Vec<Value>> = (0..n as i64).map(|i| vec![Value::I64(i)]).collect();
        let scores = XlaSink::exec_batch(&svc, "relax", &batch, &mem).unwrap();
        assert_eq!(scores.len(), n);
        // All rows identical → all scores identical.
        assert!(scores.windows(2).all(|w| w[0] == w[1]));
        let batches = svc.batches.lock().unwrap().clone();
        assert_eq!(batches.iter().sum::<usize>(), n);
    }

    #[test]
    fn sim_datapath_matches_scalar() {
        let Some(rt) = runtime() else { return };
        let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mut xla = RelaxXla::new(rt, m, 1).unwrap();
        let mut mem = crate::interp::Memory::new(m);
        let init: Vec<f32> = (0..4 * F).map(|i| 0.1 + (i % 7) as f32 * 0.05).collect();
        mem.fill_f32(m.global_by_name("feat").unwrap(), &init);
        let batch: Vec<Vec<Value>> = (0..4i64).map(|n| vec![Value::I64(n)]).collect();
        let scores = crate::sim::SimXla::exec_batch(&mut xla, "relax", &batch, &mut mem).unwrap();

        let (w, b) = relax::weights(1);
        let mut feat = init.clone();
        for (n, s) in scores.iter().enumerate() {
            let r = relax::scalar_relax(&[Value::I64(n as i64)], &mut feat, &w, &b).unwrap();
            assert!((s.as_i64() - r.as_i64()).abs() <= 2);
        }
    }
}
