//! The daemon's hot-session store: an LRU keyed by source id with an
//! entry capacity, an approximate byte budget, and the donor lookups
//! behind cross-source dedup.

use crate::lower::{CompileOptions, CompileSession};

/// One resident session.
pub struct CacheEntry {
    pub id: String,
    pub session: CompileSession,
    /// FNV-1a of the exact source text — the identical-content dedup key.
    pub content_fp: u64,
    /// [`CompileSession::approx_bytes`] at insert time.
    pub bytes: usize,
    /// LRU clock stamp (larger = more recently used).
    last_used: u64,
}

/// LRU over [`CacheEntry`]s. Not thread-safe by itself — the server
/// holds it behind a mutex and keeps compile work *outside* the lock.
pub struct SessionCache {
    entries: Vec<CacheEntry>,
    capacity: usize,
    byte_budget: usize,
    clock: u64,
    evictions: u64,
}

/// Content fingerprint of a source text (FNV-1a, same constants as the
/// AST fingerprints in `lower/batch.rs` but over raw bytes — this keys
/// *textual* identity, pre-parse).
pub fn content_fp(source: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in source.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl SessionCache {
    pub fn new(capacity: usize, byte_budget: usize) -> SessionCache {
        SessionCache {
            entries: Vec::new(),
            capacity: capacity.max(1),
            byte_budget,
            clock: 0,
            evictions: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Remove and return the entry for `id` compiled under `opts`. An id
    /// cached under *different* options is left alone (it is not a warm
    /// hit — the caller recompiles cold and the insert may then evict
    /// it). Taking (rather than borrowing) lets the server recompile
    /// outside the cache lock.
    pub fn take(&mut self, id: &str, opts: &CompileOptions) -> Option<CacheEntry> {
        let i = self
            .entries
            .iter()
            .position(|e| e.id == id && e.session.options() == opts)?;
        Some(self.entries.swap_remove(i))
    }

    /// Remove and return the entry for `id` under *any* options
    /// (codegen serves whatever compilation the id currently holds).
    pub fn take_any(&mut self, id: &str) -> Option<CacheEntry> {
        let i = self.entries.iter().position(|e| e.id == id)?;
        Some(self.entries.swap_remove(i))
    }

    /// Drop any entry for `id` regardless of options (an id being
    /// re-registered under new options must not leave a stale twin).
    pub fn remove(&mut self, id: &str) {
        self.entries.retain(|e| e.id != id);
    }

    /// Donor session for seeding a *new* id compiled under `opts`:
    /// an identical-content entry if one exists (first preference — the
    /// seed is then a whole-compilation share), otherwise the most
    /// recently used entry with the same options (template variants are
    /// usually edits of whatever was just compiled). Returns
    /// `(session, identical_content)`.
    pub fn donor(&self, fp: u64, opts: &CompileOptions) -> Option<(&CompileSession, bool)> {
        if let Some(e) = self
            .entries
            .iter()
            .filter(|e| e.content_fp == fp && e.session.options() == opts)
            .max_by_key(|e| e.last_used)
        {
            return Some((&e.session, true));
        }
        self.entries
            .iter()
            .filter(|e| e.session.options() == opts)
            .max_by_key(|e| e.last_used)
            .map(|e| (&e.session, false))
    }

    /// Insert (or re-admit) an entry as most-recently-used, then evict
    /// least-recently-used entries until both the capacity and the byte
    /// budget hold. The newest entry is never evicted, so one
    /// over-budget session still caches. Returns how many entries were
    /// evicted by this insert.
    pub fn insert(&mut self, mut entry: CacheEntry) -> usize {
        self.remove(&entry.id);
        entry.last_used = self.tick();
        self.entries.push(entry);
        let mut evicted = 0usize;
        while self.entries.len() > 1
            && (self.entries.len() > self.capacity || self.total_bytes() > self.byte_budget)
        {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .expect("len > 1");
            self.entries.swap_remove(lru);
            evicted += 1;
        }
        self.evictions += evicted as u64;
        evicted
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Total LRU evictions over the cache's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn contains(&self, id: &str) -> bool {
        self.entries.iter().any(|e| e.id == id)
    }

    /// Entries in no particular order (for `stats`).
    pub fn iter(&self) -> impl Iterator<Item = &CacheEntry> {
        self.entries.iter()
    }
}

/// Build a cache entry around a session (stamps bytes + content fp).
pub fn entry_for(id: &str, source: &str, session: CompileSession) -> CacheEntry {
    CacheEntry {
        id: id.to_string(),
        bytes: session.approx_bytes(),
        content_fp: content_fp(source),
        session,
        last_used: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session(name: &str, src: &str) -> CompileSession {
        CompileSession::new(name, src, &CompileOptions::standard()).unwrap()
    }

    const A: &str = "int f(int n) { return n + 1; }";
    const B: &str = "int g(int n) { return n + 2; }";
    const C: &str = "int h(int n) { return n + 3; }";

    #[test]
    fn lru_evicts_oldest_and_counts() {
        let mut cache = SessionCache::new(2, usize::MAX);
        assert_eq!(cache.insert(entry_for("a", A, session("a", A))), 0);
        assert_eq!(cache.insert(entry_for("b", B, session("b", B))), 0);
        // Touch "a" so "b" becomes LRU.
        let opts = CompileOptions::standard();
        let a = cache.take("a", &opts).unwrap();
        cache.insert(a);
        assert_eq!(cache.insert(entry_for("c", C, session("c", C))), 1);
        assert!(cache.contains("a") && cache.contains("c") && !cache.contains("b"));
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn byte_budget_keeps_newest() {
        // Budget of one byte: every insert over-runs it, but the newest
        // entry always stays resident.
        let mut cache = SessionCache::new(8, 1);
        cache.insert(entry_for("a", A, session("a", A)));
        assert_eq!(cache.len(), 1);
        cache.insert(entry_for("b", B, session("b", B)));
        assert_eq!(cache.len(), 1);
        assert!(cache.contains("b"));
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn take_respects_options() {
        let mut cache = SessionCache::new(4, usize::MAX);
        cache.insert(entry_for("a", A, session("a", A)));
        assert!(cache.take("a", &CompileOptions::no_dae()).is_none());
        assert!(cache.take("a", &CompileOptions::standard()).is_some());
        assert!(cache.is_empty());
    }

    #[test]
    fn donor_prefers_identical_content() {
        let mut cache = SessionCache::new(4, usize::MAX);
        cache.insert(entry_for("a", A, session("a", A)));
        cache.insert(entry_for("b", B, session("b", B)));
        let opts = CompileOptions::standard();
        let (donor, identical) = cache.donor(content_fp(A), &opts).unwrap();
        assert!(identical);
        assert_eq!(donor.name(), "a");
        // Unknown content: falls back to the MRU entry.
        let (donor, identical) = cache.donor(content_fp(C), &opts).unwrap();
        assert!(!identical);
        assert_eq!(donor.name(), "b");
        assert!(cache.donor(content_fp(A), &CompileOptions::no_dae()).is_none());
    }
}
