//! Client side of the serve protocol: a thin blocking wrapper used by
//! `bombyx client`, the integration tests and `serve_bench`.

use std::os::unix::net::UnixStream;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::proto;

/// One connection to a running daemon. Requests are synchronous:
/// write a frame, read the matching response frame.
pub struct Client {
    stream: UnixStream,
}

impl Client {
    pub fn connect(socket: impl AsRef<Path>) -> Result<Client> {
        let socket = socket.as_ref();
        let stream = UnixStream::connect(socket)
            .with_context(|| format!("connecting to {}", socket.display()))?;
        Ok(Client { stream })
    }

    /// Send a raw request object and wait for the response.
    pub fn request(&mut self, msg: &Json) -> Result<Json> {
        proto::write_frame(&mut self.stream, msg)?;
        proto::read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("server closed the connection before responding"))
    }

    /// `compile`: register (or update) source `id`. Extra knobs ride on
    /// `extend` — e.g. `{"echo": true}` or `{"no_dae": true}`.
    pub fn compile(&mut self, id: &str, source: &str) -> Result<Json> {
        self.compile_with(id, source, |_| {})
    }

    pub fn compile_with(
        &mut self,
        id: &str,
        source: &str,
        extend: impl FnOnce(&mut Json),
    ) -> Result<Json> {
        let mut msg = Json::object();
        msg.set("op", "compile");
        msg.set("id", id);
        msg.set("source", source);
        extend(&mut msg);
        self.request(&msg)
    }

    /// `recompile`: an edit to a (hopefully cached) id.
    pub fn recompile(&mut self, id: &str, source: &str) -> Result<Json> {
        self.recompile_with(id, source, |_| {})
    }

    pub fn recompile_with(
        &mut self,
        id: &str,
        source: &str,
        extend: impl FnOnce(&mut Json),
    ) -> Result<Json> {
        let mut msg = Json::object();
        msg.set("op", "recompile");
        msg.set("id", id);
        msg.set("source", source);
        extend(&mut msg);
        self.request(&msg)
    }

    /// `batch`: compile many `(id, source)` units server-side, sharded
    /// over `jobs` workers (0 = server default).
    pub fn batch(&mut self, items: &[(&str, &str)], jobs: usize) -> Result<Json> {
        let rendered: Vec<Json> = items
            .iter()
            .map(|(id, source)| {
                let mut item = Json::object();
                item.set("id", *id);
                item.set("source", *source);
                item
            })
            .collect();
        let mut msg = Json::object();
        msg.set("op", "batch");
        msg.set("items", Json::Array(rendered));
        msg.set("jobs", jobs);
        self.request(&msg)
    }

    /// `codegen` for a cached id (`source: None`) or with an inline
    /// source to compile on miss.
    pub fn codegen(&mut self, id: &str, target: &str, source: Option<&str>) -> Result<Json> {
        let mut msg = Json::object();
        msg.set("op", "codegen");
        msg.set("id", id);
        msg.set("target", target);
        if let Some(source) = source {
            msg.set("source", source);
        }
        self.request(&msg)
    }

    pub fn stats(&mut self) -> Result<Json> {
        let mut msg = Json::object();
        msg.set("op", "stats");
        self.request(&msg)
    }

    /// Ask the daemon to shut down (the response arrives before the
    /// listener stops accepting).
    pub fn shutdown(&mut self) -> Result<Json> {
        let mut msg = Json::object();
        msg.set("op", "shutdown");
        self.request(&msg)
    }
}

/// Fail with the server-rendered error unless `resp.ok == true`.
pub fn expect_ok(resp: &Json) -> Result<&Json> {
    if resp.get("ok") == Some(&Json::Bool(true)) {
        return Ok(resp);
    }
    match resp.get("error").and_then(Json::as_str) {
        Some(e) => bail!("server error: {e}"),
        None => bail!("server error: {}", resp.compact()),
    }
}
