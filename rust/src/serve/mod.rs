//! Compile-as-a-service: the resident `bombyx serve` daemon.
//!
//! Every CLI invocation pays cold parse/sema/lowering; the daemon doesn't.
//! It holds hot [`CompileSession`]s keyed by client-chosen source id in an
//! LRU ([`cache::SessionCache`] — configurable entry capacity and byte
//! budget, evictions counted), and serves concurrent clients over a
//! unix-domain socket with a 4-byte big-endian length-prefixed JSON
//! protocol ([`proto`]). Warm paths stack:
//!
//! - an **edit to a cached id** routes to [`CompileSession::recompile`] —
//!   function-granular incremental splicing, full pipeline only on
//!   structural change;
//! - a **new id with known content** (identical template source) shares
//!   the donor's compilation wholesale via
//!   [`CompileSession::new_seeded`] (`Arc` bumps, zero pass work);
//! - a **new id near a cached source** (template variant, same options)
//!   re-lowers only the differing functions against the most recently
//!   used donor;
//! - **batched requests** shard over [`crate::util::parallel::shard_map`].
//!
//! Requests: `compile`, `recompile`, `codegen` (`--target
//! emu|hardcilk|rtl`), `batch`, `stats`, `shutdown`. Every request gets a
//! `serve`-category span, `serve.*` counters/histograms through
//! [`crate::obs`], and (with logging on) a one-line compact-JSON record —
//! see `rust/src/obs/README.md` for the schema. Shutdown drains in-flight
//! requests before the listener thread exits.
//!
//! [`CompileSession`]: crate::lower::CompileSession
//! [`CompileSession::recompile`]: crate::lower::CompileSession::recompile
//! [`CompileSession::new_seeded`]: crate::lower::CompileSession::new_seeded

pub mod cache;
pub mod client;
pub mod proto;
pub mod server;

use std::path::PathBuf;

pub use cache::SessionCache;
pub use client::{expect_ok, Client};
pub use server::{Server, ServeStatsSnapshot};

/// Daemon configuration (the CLI's `serve` flags map 1:1 onto this).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Unix-domain socket path. A stale file at this path is replaced.
    pub socket: PathBuf,
    /// Max resident sessions before LRU eviction.
    pub capacity: usize,
    /// Approximate byte budget across resident sessions
    /// ([`crate::lower::CompileSession::approx_bytes`]); the LRU evicts
    /// past it, but always keeps at least the most recent entry.
    pub byte_budget: usize,
    /// Emit a one-line compact-JSON record per request on stdout.
    pub log: bool,
}

impl ServeConfig {
    pub fn new(socket: impl Into<PathBuf>) -> ServeConfig {
        ServeConfig {
            socket: socket.into(),
            capacity: 64,
            byte_budget: 64 * 1024 * 1024,
            log: false,
        }
    }
}
