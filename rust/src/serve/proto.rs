//! Length-prefixed JSON framing over a byte stream.
//!
//! One frame = a 4-byte big-endian payload length followed by that many
//! bytes of compact (single-line) JSON. Both sides of the socket speak
//! the same frames; requests and responses are plain [`Json`] objects.

use std::io::{ErrorKind, Read, Write};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Upper bound on one frame (16 MiB) — a corrupt length prefix must not
/// allocate unbounded memory.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Write one frame.
pub fn write_frame(stream: &mut impl Write, msg: &Json) -> Result<()> {
    let payload = msg.compact();
    let bytes = payload.as_bytes();
    if bytes.len() as u64 > MAX_FRAME as u64 {
        bail!("frame too large: {} bytes (cap {MAX_FRAME})", bytes.len());
    }
    stream.write_all(&(bytes.len() as u32).to_be_bytes())?;
    stream.write_all(bytes)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame, blocking until it arrives. `Ok(None)` on a clean EOF
/// before the first length byte (the peer closed between frames).
pub fn read_frame(stream: &mut impl Read) -> Result<Option<Json>> {
    read_frame_poll(stream, || true)
}

/// Read one frame from a stream that may have a read timeout armed.
///
/// Idle timeouts *between* frames consult `keep_waiting`: while it
/// returns true the read retries, otherwise `Ok(None)`. This is how the
/// daemon's connection handlers notice a shutdown without dropping a
/// request that is mid-frame — once the first byte of a frame has
/// arrived, timeouts always retry, so an in-flight request is fully
/// drained before the handler exits.
pub fn read_frame_poll(
    stream: &mut impl Read,
    mut keep_waiting: impl FnMut() -> bool,
) -> Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                bail!("connection closed mid-frame ({got} of 4 length bytes)");
            }
            Ok(n) => got += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if got == 0 && !keep_waiting() {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds the {MAX_FRAME}-byte cap");
    }
    let mut payload = vec![0u8; len as usize];
    let mut got = 0usize;
    while got < payload.len() {
        match stream.read(&mut payload[got..]) {
            Ok(0) => bail!("connection closed mid-frame ({got} of {len} payload bytes)"),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    let text = String::from_utf8(payload).context("frame payload is not UTF-8")?;
    let msg = json::parse(&text).map_err(|e| anyhow!("bad frame JSON: {e}"))?;
    Ok(Some(msg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut req = Json::object();
        req.set("op", "compile");
        req.set("id", "fib");
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &req).unwrap();
        // 4-byte BE length prefix over the compact payload.
        let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        assert_eq!(len, buf.len() - 4);
        let mut cursor = std::io::Cursor::new(buf);
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(back, req);
        // A second read hits clean EOF.
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn oversized_length_prefix_rejected() {
        let mut buf = (MAX_FRAME + 1).to_be_bytes().to_vec();
        buf.extend_from_slice(b"xxxx");
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Json::Str("hello".into())).unwrap();
        buf.truncate(buf.len() - 2);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }
}
