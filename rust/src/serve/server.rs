//! The daemon: accept loop, per-connection handlers, request dispatch.
//!
//! Locking discipline: the session cache mutex is held only for lookups
//! and inserts — all parse/lower/recompile work runs outside it, so
//! concurrent clients compile in parallel and only serialize on the
//! (cheap) cache bookkeeping.

use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::explicit::explicit_tasks;
use crate::ir::print::print_module;
use crate::lower::{CompileOptions, CompileSession, RecompileMode, SessionSeed};
use crate::obs;
use crate::util::json::Json;
use crate::util::parallel;

use super::cache::{self, CacheEntry, SessionCache};
use super::{proto, ServeConfig};

/// How often an idle connection handler re-checks the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

#[derive(Default)]
struct Stats {
    requests: AtomicU64,
    errors: AtomicU64,
    compiles: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    dedup_hits: AtomicU64,
    dedup_spliced: AtomicU64,
}

/// Point-in-time copy of the daemon's counters (the `stats` op renders
/// the same numbers over the wire).
#[derive(Clone, Debug, Default)]
pub struct ServeStatsSnapshot {
    pub requests: u64,
    pub errors: u64,
    /// Compile units processed (single requests + batch items).
    pub compiles: u64,
    /// Warm hits: an edit routed to a cached session's `recompile`.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Identical-content misses served by sharing a donor compilation.
    pub dedup_hits: u64,
    /// Near-identical misses served by splicing against a donor.
    pub dedup_spliced: u64,
    /// LRU evictions over the daemon's lifetime.
    pub evictions: u64,
    pub sessions: usize,
    pub bytes: usize,
}

struct Inner {
    config: ServeConfig,
    listener: UnixListener,
    shutting_down: AtomicBool,
    cache: Mutex<SessionCache>,
    stats: Stats,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Inner {
    fn begin_shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        // The accept loop is blocked in `incoming()`; a throwaway
        // connection wakes it so it can observe the flag.
        let _ = UnixStream::connect(&self.config.socket);
    }

    fn snapshot(&self) -> ServeStatsSnapshot {
        let cache = self.cache.lock().expect("cache mutex");
        ServeStatsSnapshot {
            requests: self.stats.requests.load(Ordering::Relaxed),
            errors: self.stats.errors.load(Ordering::Relaxed),
            compiles: self.stats.compiles.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.stats.cache_misses.load(Ordering::Relaxed),
            dedup_hits: self.stats.dedup_hits.load(Ordering::Relaxed),
            dedup_spliced: self.stats.dedup_spliced.load(Ordering::Relaxed),
            evictions: cache.evictions(),
            sessions: cache.len(),
            bytes: cache.total_bytes(),
        }
    }
}

/// A running daemon. Dropping the handle does NOT stop it — call
/// [`Server::shutdown`] (or send the `shutdown` op) and then
/// [`Server::join`].
pub struct Server {
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind the socket (replacing a stale file) and start serving.
    pub fn start(config: ServeConfig) -> Result<Server> {
        if config.socket.exists() {
            std::fs::remove_file(&config.socket)
                .with_context(|| format!("removing stale socket {}", config.socket.display()))?;
        }
        let listener = UnixListener::bind(&config.socket)
            .with_context(|| format!("binding {}", config.socket.display()))?;
        let cache = SessionCache::new(config.capacity, config.byte_budget);
        let inner = Arc::new(Inner {
            config,
            listener,
            shutting_down: AtomicBool::new(false),
            cache: Mutex::new(cache),
            stats: Stats::default(),
            conns: Mutex::new(Vec::new()),
        });
        let accept_inner = Arc::clone(&inner);
        let accept = thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(accept_inner))
            .context("spawning the accept thread")?;
        Ok(Server { inner, accept: Some(accept) })
    }

    pub fn socket(&self) -> &Path {
        &self.inner.config.socket
    }

    /// In-process stats (benches read these without a socket roundtrip).
    pub fn stats(&self) -> ServeStatsSnapshot {
        self.inner.snapshot()
    }

    /// Trigger shutdown locally (equivalent to a client `shutdown` op).
    pub fn shutdown(&self) {
        self.inner.begin_shutdown();
    }

    /// Block until shutdown is triggered, drain every connection handler
    /// (in-flight requests complete and get their responses), then
    /// remove the socket file.
    pub fn join(mut self) -> Result<ServeStatsSnapshot> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow!("the accept thread panicked"))?;
        }
        let handles = std::mem::take(&mut *self.inner.conns.lock().expect("conns mutex"));
        for h in handles {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.inner.config.socket);
        Ok(self.inner.snapshot())
    }
}

fn accept_loop(inner: Arc<Inner>) {
    for stream in inner.listener.incoming() {
        if inner.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
        let conn_inner = Arc::clone(&inner);
        let spawned = thread::Builder::new()
            .name("serve-conn".into())
            .spawn(move || handle_conn(conn_inner, stream));
        if let Ok(handle) = spawned {
            let mut conns = inner.conns.lock().expect("conns mutex");
            conns.retain(|h| !h.is_finished());
            conns.push(handle);
        }
    }
}

fn handle_conn(inner: Arc<Inner>, mut stream: UnixStream) {
    loop {
        let req = match proto::read_frame_poll(&mut stream, || {
            !inner.shutting_down.load(Ordering::SeqCst)
        }) {
            Ok(Some(req)) => req,
            // Clean EOF, or shutdown observed while idle between frames.
            Ok(None) => break,
            // Protocol corruption is per-connection: drop it, the daemon
            // (and every other client) keeps running.
            Err(_) => break,
        };
        let (resp, shutdown) = dispatch(&inner, &req);
        if proto::write_frame(&mut stream, &resp).is_err() {
            break;
        }
        if shutdown {
            inner.begin_shutdown();
            break;
        }
    }
}

fn str_field<'a>(msg: &'a Json, key: &str) -> Result<&'a str> {
    msg.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("request is missing string field `{key}`"))
}

fn truthy(msg: &Json, key: &str) -> bool {
    matches!(msg.get(key), Some(Json::Bool(true)))
}

/// Same option resolution as the CLI's `load_session`: DAE on via the
/// `dae` flag or a `#pragma bombyx dae` in the source; `no_dae` wins.
fn options_for(msg: &Json, source: &str) -> CompileOptions {
    let has_pragma = source
        .lines()
        .any(|l| l.split("//").next().unwrap_or("").contains("#pragma bombyx dae"));
    let dae = !truthy(msg, "no_dae") && (truthy(msg, "dae") || has_pragma);
    if dae {
        CompileOptions::standard()
    } else {
        CompileOptions::no_dae()
    }
}

fn dispatch(inner: &Inner, req: &Json) -> (Json, bool) {
    let t0 = Instant::now();
    let op = req.get("op").and_then(Json::as_str).unwrap_or("").to_string();
    let id = req.get("id").and_then(Json::as_str).unwrap_or("-").to_string();
    let _span = obs::Span::enter(format!("serve {op} {id}"), "serve");
    inner.stats.requests.fetch_add(1, Ordering::Relaxed);
    obs::metrics::counter_add("serve.requests", 1);
    let op_key = if op.is_empty() { "unknown" } else { op.as_str() };
    obs::metrics::counter_add(&format!("serve.requests.{op_key}"), 1);
    let result: Result<(Json, bool)> = match op.as_str() {
        "compile" | "recompile" => op_compile(inner, &op, req).map(|r| (r, false)),
        "batch" => op_batch(inner, req).map(|r| (r, false)),
        "codegen" => op_codegen(inner, req).map(|r| (r, false)),
        "stats" => op_stats(inner).map(|r| (r, false)),
        "shutdown" => {
            let mut resp = Json::object();
            resp.set("ok", true);
            Ok((resp, true))
        }
        other => Err(anyhow!("unknown op `{other}`")),
    };
    let (mut resp, shutdown) = match result {
        Ok(v) => v,
        Err(e) => {
            inner.stats.errors.fetch_add(1, Ordering::Relaxed);
            obs::metrics::counter_add("serve.errors", 1);
            let mut r = Json::object();
            r.set("ok", false);
            r.set("error", format!("{e:#}"));
            (r, false)
        }
    };
    let elapsed = t0.elapsed();
    obs::metrics::observe_ms("serve.request_ms", elapsed);
    obs::metrics::observe_ms(&format!("serve.request_ms.{op_key}"), elapsed);
    resp.set("ms", elapsed.as_secs_f64() * 1e3);
    // Compile-shaped ops log per compile unit (in `compile_prepared`);
    // everything else gets its line here.
    if !matches!(op.as_str(), "compile" | "recompile" | "batch") {
        let ok = resp.get("ok") == Some(&Json::Bool(true));
        log_record(inner, op_key, &id, ok, "-", elapsed);
    }
    (resp, shutdown)
}

fn log_record(inner: &Inner, op: &str, id: &str, ok: bool, mode: &str, d: Duration) {
    if !inner.config.log {
        return;
    }
    let mut rec = Json::object();
    rec.set("event", "serve.request");
    rec.set("op", op);
    rec.set("id", id);
    rec.set("ok", ok);
    rec.set("mode", mode);
    rec.set("ms", d.as_secs_f64() * 1e3);
    println!("{}", rec.compact());
}

/// A compile unit with its cache context resolved (under one short
/// lock), ready to run lock-free.
struct Prepared {
    op: String,
    id: String,
    source: String,
    opts: CompileOptions,
    echo: bool,
    /// The id's resident session, removed from the cache for the warm
    /// `recompile` path.
    cached: Option<CacheEntry>,
    /// Dedup donor for the miss path (a cheap shared clone; the
    /// original stays resident).
    donor: Option<CompileSession>,
}

fn prepare(inner: &Inner, op: &str, msg: &Json, id: &str, source: &str) -> Prepared {
    let opts = options_for(msg, source);
    let mut cache = inner.cache.lock().expect("cache mutex");
    let cached = cache.take(id, &opts);
    let donor = if cached.is_none() {
        cache
            .donor(cache::content_fp(source), &opts)
            .map(|(donor, _identical)| donor.clone_shared(id))
    } else {
        None
    };
    Prepared {
        op: op.to_string(),
        id: id.to_string(),
        source: source.to_string(),
        opts,
        echo: truthy(msg, "echo"),
        cached,
        donor,
    }
}

/// Run one compile unit. Returns the entry to (re)insert — `None` only
/// when there is nothing valid to cache — plus the response object.
fn compile_prepared(inner: &Inner, mut p: Prepared) -> (Option<CacheEntry>, Json) {
    let t0 = Instant::now();
    inner.stats.compiles.fetch_add(1, Ordering::Relaxed);
    obs::metrics::counter_add("serve.compiles", 1);
    let mut resp = Json::object();
    resp.set("id", p.id.as_str());

    let outcome: Result<(CacheEntry, &'static str, Vec<String>, bool)> =
        if let Some(mut entry) = p.cached.take() {
            match entry.session.recompile(&p.source) {
                Ok(out) => {
                    let mode = match out.mode {
                        RecompileMode::Unchanged => "unchanged",
                        RecompileMode::Incremental => "incremental",
                        RecompileMode::Full => "full",
                    };
                    entry.content_fp = cache::content_fp(&p.source);
                    entry.bytes = entry.session.approx_bytes();
                    Ok((entry, mode, out.dirty, true))
                }
                Err(e) => {
                    // `recompile` fails before installing anything, so
                    // the cached compilation is still the last good one
                    // — keep it warm instead of punishing the id.
                    p.cached = Some(entry);
                    Err(e)
                }
            }
        } else {
            match CompileSession::new_seeded(&p.id, &p.source, &p.opts, p.donor.as_ref()) {
                Ok((session, seed)) => {
                    let (mode, dirty) = match seed {
                        SessionSeed::Identical => ("identical", Vec::new()),
                        SessionSeed::Spliced { dirty } => ("spliced", dirty),
                        SessionSeed::Cold => ("cold", Vec::new()),
                    };
                    Ok((cache::entry_for(&p.id, &p.source, session), mode, dirty, false))
                }
                Err(e) => Err(e),
            }
        };

    match outcome {
        Ok((entry, mode, dirty, warm)) => {
            if warm {
                inner.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                obs::metrics::counter_add("serve.cache_hits", 1);
            } else {
                inner.stats.cache_misses.fetch_add(1, Ordering::Relaxed);
                obs::metrics::counter_add("serve.cache_misses", 1);
                match mode {
                    "identical" => {
                        inner.stats.dedup_hits.fetch_add(1, Ordering::Relaxed);
                        obs::metrics::counter_add("serve.dedup_hits", 1);
                    }
                    "spliced" => {
                        inner.stats.dedup_spliced.fetch_add(1, Ordering::Relaxed);
                        obs::metrics::counter_add("serve.dedup_spliced", 1);
                    }
                    _ => {}
                }
            }
            resp.set("ok", true);
            resp.set("mode", mode);
            resp.set("warm", warm);
            resp.set(
                "dirty",
                Json::Array(dirty.iter().map(|d| Json::from(d.as_str())).collect()),
            );
            resp.set("tasks", explicit_tasks(entry.session.explicit()).len());
            if p.echo {
                resp.set("ir", print_module(entry.session.explicit()));
            }
            let elapsed = t0.elapsed();
            obs::metrics::observe_ms("serve.compile_ms", elapsed);
            resp.set("compile_ms", elapsed.as_secs_f64() * 1e3);
            log_record(inner, &p.op, &p.id, true, mode, elapsed);
            (Some(entry), resp)
        }
        Err(e) => {
            inner.stats.errors.fetch_add(1, Ordering::Relaxed);
            obs::metrics::counter_add("serve.errors", 1);
            resp.set("ok", false);
            resp.set("error", format!("{e:#}"));
            log_record(inner, &p.op, &p.id, false, "error", t0.elapsed());
            (p.cached, resp)
        }
    }
}

fn op_compile(inner: &Inner, op: &str, req: &Json) -> Result<Json> {
    let id = str_field(req, "id")?;
    let source = str_field(req, "source")?;
    let p = prepare(inner, op, req, id, source);
    let (entry, mut resp) = compile_prepared(inner, p);
    let evicted = match entry {
        Some(entry) => inner.cache.lock().expect("cache mutex").insert(entry),
        None => 0,
    };
    obs::metrics::counter_add("serve.evictions", evicted as u64);
    resp.set("evicted", evicted);
    Ok(resp)
}

fn op_batch(inner: &Inner, req: &Json) -> Result<Json> {
    let items = req
        .get("items")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("batch request needs an `items` array"))?;
    if items.is_empty() {
        let mut resp = Json::object();
        resp.set("ok", true);
        resp.set("results", Json::Array(Vec::new()));
        resp.set("jobs", 0usize);
        return Ok(resp);
    }
    let jobs = req.get("jobs").and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
    // Resolve cache context sequentially (short locks), then shard the
    // actual compile work. Each slot is consumed exactly once.
    let mut prepared: Vec<Mutex<Option<Prepared>>> = Vec::with_capacity(items.len());
    for item in items {
        let id = str_field(item, "id")?;
        let source = str_field(item, "source")?;
        prepared.push(Mutex::new(Some(prepare(inner, "batch", item, id, source))));
    }
    let workers = if jobs == 0 {
        parallel::default_workers(prepared.len())
    } else {
        jobs.min(prepared.len().max(1))
    };
    let results = parallel::shard_map(&prepared, workers, |slot| {
        let p = slot.lock().expect("slot mutex").take().expect("each slot taken once");
        compile_prepared(inner, p)
    });
    let mut evicted = 0usize;
    let mut rendered = Vec::with_capacity(results.len());
    {
        let mut cache = inner.cache.lock().expect("cache mutex");
        for (entry, item_resp) in results {
            if let Some(entry) = entry {
                evicted += cache.insert(entry);
            }
            rendered.push(item_resp);
        }
    }
    obs::metrics::counter_add("serve.evictions", evicted as u64);
    let mut resp = Json::object();
    resp.set("ok", true);
    resp.set("results", Json::Array(rendered));
    resp.set("jobs", workers);
    resp.set("evicted", evicted);
    Ok(resp)
}

fn op_codegen(inner: &Inner, req: &Json) -> Result<Json> {
    let id = str_field(req, "id")?;
    let target = req.get("target").and_then(Json::as_str).unwrap_or("emu");
    let system = req.get("system").and_then(Json::as_str).unwrap_or("bombyx_system");
    let dump = truthy(req, "dump");
    let cached = inner.cache.lock().expect("cache mutex").take_any(id);
    let mut entry = match cached {
        Some(entry) => {
            inner.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            obs::metrics::counter_add("serve.cache_hits", 1);
            entry
        }
        None => {
            let source = str_field(req, "source")
                .context("codegen for an uncached id needs `source`")?;
            let p = prepare(inner, "codegen", req, id, source);
            let (entry, resp) = compile_prepared(inner, p);
            match entry {
                Some(entry) => entry,
                // Compile failed; the structured error is in `resp`.
                None => return Ok(resp),
            }
        }
    };
    let rendered = render_codegen(&mut entry.session, id, target, system, dump);
    // Reinsert before surfacing any codegen error: a bad target name
    // must not evict a perfectly good session.
    entry.bytes = entry.session.approx_bytes();
    let evicted = inner.cache.lock().expect("cache mutex").insert(entry);
    obs::metrics::counter_add("serve.evictions", evicted as u64);
    rendered
}

fn render_codegen(
    session: &mut CompileSession,
    id: &str,
    target: &str,
    system: &str,
    dump: bool,
) -> Result<Json> {
    let mut resp = Json::object();
    resp.set("ok", true);
    resp.set("id", id);
    resp.set("target", target);
    match target {
        "emu" => {
            let prog = session.emu_program();
            resp.set(
                "entries",
                Json::Array(prog.entries.iter().map(|e| Json::from(e.as_str())).collect()),
            );
        }
        "hardcilk" => {
            let sys = session.hardcilk_system(system)?;
            resp.set("pes", sys.pes.len());
            resp.set("loc", sys.total_loc());
            if dump {
                resp.set("descriptor", sys.descriptor.clone());
            }
        }
        "rtl" => {
            let sys = session.rtl_system(system)?;
            resp.set("pes", sys.pes.len());
            resp.set("loc", sys.total_loc());
            if dump {
                resp.set("verilog", sys.concatenated());
            }
        }
        other => bail!("unknown codegen target `{other}` (expected emu|hardcilk|rtl)"),
    }
    Ok(resp)
}

fn op_stats(inner: &Inner) -> Result<Json> {
    let snap = inner.snapshot();
    let mut resp = Json::object();
    resp.set("ok", true);
    resp.set("sessions", snap.sessions);
    resp.set("bytes", snap.bytes);
    resp.set("capacity", inner.config.capacity);
    resp.set("byte_budget", inner.config.byte_budget);
    resp.set("requests", snap.requests as i64);
    resp.set("compiles", snap.compiles as i64);
    resp.set("errors", snap.errors as i64);
    resp.set("cache_hits", snap.cache_hits as i64);
    resp.set("cache_misses", snap.cache_misses as i64);
    resp.set("dedup_hits", snap.dedup_hits as i64);
    resp.set("dedup_spliced", snap.dedup_spliced as i64);
    resp.set("evictions", snap.evictions as i64);
    let entries: Vec<Json> = {
        let cache = inner.cache.lock().expect("cache mutex");
        cache
            .iter()
            .map(|e| {
                let mut row = Json::object();
                row.set("id", e.id.as_str());
                row.set("bytes", e.bytes);
                if let Some(fp) = e.session.structure_fp() {
                    row.set("structure_fp", format!("{fp:016x}"));
                }
                row
            })
            .collect()
    };
    resp.set("entries", Json::Array(entries));
    Ok(resp)
}
