//! HBM-like memory channel model.
//!
//! Three parameters: fixed service latency `L`, maximum outstanding
//! requests `M` (MSHR-style slots), and minimum issue interval `B`
//! (bandwidth). A request arriving at `t` starts service at
//! `max(t, earliest free slot, last_start + B)` and responds `L` cycles
//! later. This gives pipelined requesters up to `M`-way latency overlap —
//! the resource the DAE access PE exploits and the fused PE cannot
//! (paper §II-C).

use std::collections::BinaryHeap;

#[derive(Clone, Debug, Default)]
pub struct ChannelStats {
    pub requests: u64,
    /// Total cycles requests spent queued before service start.
    pub queue_cycles: u64,
    /// Peak concurrently-outstanding requests.
    pub peak_outstanding: u32,
}

pub struct MemChannel {
    latency: u64,
    issue_interval: u64,
    /// Free-at times of the M slots (min-heap via Reverse).
    slots: BinaryHeap<std::cmp::Reverse<u64>>,
    /// Earliest time the next request may start (bandwidth pacing).
    next_issue: u64,
    pub stats: ChannelStats,
}

impl MemChannel {
    pub fn new(latency: u32, outstanding: u32, issue_interval: u32) -> MemChannel {
        let mut slots = BinaryHeap::new();
        for _ in 0..outstanding.max(1) {
            slots.push(std::cmp::Reverse(0u64));
        }
        MemChannel {
            latency: latency as u64,
            issue_interval: issue_interval.max(1) as u64,
            slots,
            next_issue: 0,
            stats: ChannelStats::default(),
        }
    }

    /// Issue a request at time `t`; returns the response time.
    pub fn request(&mut self, t: u64) -> u64 {
        let std::cmp::Reverse(slot_free) = self.slots.pop().expect("channel has slots");
        let start = t.max(slot_free).max(self.next_issue);
        self.next_issue = start + self.issue_interval;
        let response = start + self.latency;
        self.slots.push(std::cmp::Reverse(response));
        self.stats.requests += 1;
        self.stats.queue_cycles += start - t;
        // Outstanding now = slots whose free time > start.
        let outstanding = self.slots.iter().filter(|std::cmp::Reverse(f)| *f > start).count();
        self.stats.peak_outstanding = self.stats.peak_outstanding.max(outstanding as u32);
        response
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_request_takes_latency() {
        let mut ch = MemChannel::new(100, 8, 4);
        assert_eq!(ch.request(10), 110);
        assert_eq!(ch.stats.queue_cycles, 0);
    }

    #[test]
    fn bandwidth_spaces_requests() {
        let mut ch = MemChannel::new(100, 8, 4);
        let r0 = ch.request(0);
        let r1 = ch.request(0);
        let r2 = ch.request(0);
        assert_eq!(r0, 100);
        assert_eq!(r1, 104);
        assert_eq!(r2, 108);
    }

    #[test]
    fn outstanding_limit_serializes() {
        let mut ch = MemChannel::new(100, 2, 1);
        let r0 = ch.request(0);
        let r1 = ch.request(0);
        let r2 = ch.request(0); // must wait for slot 0 to free at 100
        assert_eq!(r0, 100);
        assert_eq!(r1, 101);
        assert!(r2 >= 200, "third request needs a freed slot: {r2}");
        assert!(ch.stats.queue_cycles >= 100);
    }

    #[test]
    fn overlap_vs_serial_latency() {
        // M pipelined requests cost ~L + M*B; M serial (blocking) requests
        // cost M*L. This delta is the DAE win.
        let m = 8u64;
        let (lat, bw) = (120u64, 4u64);
        let mut pipe = MemChannel::new(lat as u32, m as u32, bw as u32);
        let mut last = 0;
        for _ in 0..m {
            last = pipe.request(0);
        }
        assert!(last <= lat + m * bw, "{last}");

        let mut serial = MemChannel::new(lat as u32, m as u32, bw as u32);
        let mut t = 0;
        for _ in 0..m {
            t = serial.request(t);
        }
        // Each blocking request waits the full latency (bw < L never binds).
        assert_eq!(t, m * lat);
    }
}
