//! The discrete-event simulation engine.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::exec::{ArgList, KStack, KernelMode, KernelProgram};
use crate::hls::{classify, PeClass};
use crate::interp::Memory;
use crate::ir::cfg::{FuncId, FuncKind, Module};
use crate::ir::expr::Value;

use super::channel::MemChannel;
use super::exec::{self, Effect, FnState, SCont, STask, Seg};
use super::{SimConfig, SimStats, SimXla, TaskStats};

#[derive(Clone, Debug, PartialEq, Eq)]
enum Ev {
    /// Try to dispatch queued tasks of this type.
    Dispatch(FuncId),
    /// Continue a running sequential task.
    Step(usize),
    /// Apply the deferred effects of a pipelined task instance.
    Apply(usize),
    /// Flush the XLA batch buffer (deadline-triggered).
    XlaFlush,
}

struct PeGroup {
    class: PeClass,
    /// busy-until per PE.
    busy: Vec<u64>,
    stats: TaskStats,
}

struct Running {
    task: FuncId,
    pe: usize,
    start: u64,
    trace: Vec<Seg>,
    idx: usize,
    done: bool,
}

pub struct Engine<'m, 'x> {
    module: &'m Module,
    config: &'m SimConfig,
    xla: &'x mut dyn SimXla,
    /// Compiled kernels shared with every other engine (session-cached
    /// or compiled at construction).
    kernels: Arc<KernelProgram>,
    state: FnState,
    channel: MemChannel,
    /// Task queues and PE groups, indexed by `FuncId` (dense tables —
    /// `None`/unused entries for non-task functions). The `SimConfig`'s
    /// name-keyed PE counts are resolved once here at construction, so
    /// the per-event dispatch path never touches a string or a hash map.
    queues: Vec<VecDeque<STask>>,
    groups: Vec<Option<PeGroup>>,
    events: BinaryHeap<Reverse<(u64, u64, usize)>>,
    event_payload: Vec<Ev>,
    seq: u64,
    running: Vec<Running>,
    pending: u64,
    result: Option<Value>,
    now: u64,
    max_queue_depth: usize,
    /// Reused kernel frame stack (functional execution at dispatch).
    stack: KStack,
    /// Recycled per-dispatch trace buffers: a completed task's `Vec<Seg>`
    /// returns here instead of being dropped, so steady-state dispatch
    /// allocates no trace storage.
    trace_pool: Vec<Vec<Seg>>,
    // XLA batching.
    xla_buffer: Vec<STask>,
    xla_busy_until: u64,
    xla_flush_armed: bool,
    xla_batches: u64,
}

impl<'m, 'x> Engine<'m, 'x> {
    pub fn new(
        module: &'m Module,
        memory: Memory,
        config: &'m SimConfig,
        xla: &'x mut dyn SimXla,
    ) -> Result<Engine<'m, 'x>> {
        let kernels = Arc::new(crate::exec::compile_module(module, KernelMode::Explicit)?);
        Engine::new_with_kernels(module, kernels, memory, config, xla)
    }

    pub fn new_with_kernels(
        module: &'m Module,
        kernels: Arc<KernelProgram>,
        memory: Memory,
        config: &'m SimConfig,
        xla: &'x mut dyn SimXla,
    ) -> Result<Engine<'m, 'x>> {
        let mut queues = Vec::with_capacity(module.funcs.len());
        queues.resize_with(module.funcs.len(), VecDeque::new);
        let mut groups: Vec<Option<PeGroup>> = Vec::with_capacity(module.funcs.len());
        groups.resize_with(module.funcs.len(), || None);
        for (fid, f) in module.funcs.iter() {
            if f.task.is_none() {
                continue;
            }
            let n = config.pes_for(&f.name);
            groups[fid.index()] = Some(PeGroup {
                class: classify(f),
                busy: vec![0; n as usize],
                stats: TaskStats { pes: n, ..Default::default() },
            });
        }
        Ok(Engine {
            module,
            config,
            xla,
            kernels,
            state: FnState { memory, closures: Vec::new(), live_closures: 0, closures_made: 0 },
            channel: MemChannel::new(
                config.mem_latency,
                config.mem_outstanding,
                config.mem_issue_interval,
            ),
            queues,
            groups,
            events: BinaryHeap::new(),
            event_payload: Vec::new(),
            seq: 0,
            running: Vec::new(),
            pending: 0,
            result: None,
            now: 0,
            max_queue_depth: 0,
            stack: KStack::new(),
            trace_pool: Vec::new(),
            xla_buffer: Vec::new(),
            xla_busy_until: 0,
            xla_flush_armed: false,
            xla_batches: 0,
        })
    }

    fn schedule(&mut self, time: u64, ev: Ev) {
        let idx = self.event_payload.len();
        self.event_payload.push(ev);
        self.events.push(Reverse((time, self.seq, idx)));
        self.seq += 1;
    }

    fn enqueue(&mut self, t: u64, task: STask) {
        self.pending += 1;
        let fid = task.task;
        if self.module.funcs[fid].kind == FuncKind::Xla {
            self.xla_buffer.push(task);
            if self.xla_buffer.len() >= self.config.xla_batch as usize {
                self.schedule(t.max(self.xla_busy_until), Ev::XlaFlush);
            } else if !self.xla_flush_armed {
                self.xla_flush_armed = true;
                // Flush deadline: don't let a partial batch starve.
                self.schedule(t + 4 * self.config.mem_latency as u64, Ev::XlaFlush);
            }
            return;
        }
        let q = &mut self.queues[fid.index()];
        q.push_back(task);
        self.max_queue_depth = self.max_queue_depth.max(q.len());
        // Queue-occupancy distribution (no-op unless --metrics-json).
        crate::obs::metrics::observe("sim.queue_depth", q.len() as f64);
        self.schedule(t + self.config.dispatch_latency as u64, Ev::Dispatch(fid));
    }

    pub fn run(mut self, entry: &str, args: &[Value]) -> Result<(Value, Memory, SimStats)> {
        let fid = self
            .module
            .func_by_name(entry)
            .ok_or_else(|| anyhow!("no task named `{entry}`"))?;
        self.enqueue(0, STask { task: fid, args: ArgList::from_slice(args), cont: SCont::Root });

        while let Some(Reverse((t, _, payload))) = self.events.pop() {
            self.now = t.max(self.now);
            if self.now > self.config.max_cycles {
                bail!("simulation exceeded max_cycles={}", self.config.max_cycles);
            }
            let ev = self.event_payload[payload].clone();
            match ev {
                Ev::Dispatch(fid) => self.dispatch(t, fid)?,
                Ev::Step(run) => self.step(t, run)?,
                Ev::Apply(run) => self.apply_all(t, run)?,
                Ev::XlaFlush => self.xla_flush(t)?,
            }
        }

        if self.pending != 0 {
            bail!("simulation drained with {} tasks pending (deadlock?)", self.pending);
        }
        let result = self
            .result
            .take()
            .ok_or_else(|| anyhow!("no result delivered to the root continuation"))?;
        let mut per_task: Vec<(String, TaskStats)> = Vec::new();
        for (i, group) in self.groups.iter().enumerate() {
            let Some(group) = group else { continue };
            let mut s = group.stats.clone();
            s.utilization = if self.now > 0 {
                s.busy_cycles as f64 / (self.now as f64 * s.pes as f64)
            } else {
                0.0
            };
            per_task.push((self.module.funcs[FuncId::new(i)].name.clone(), s));
        }
        per_task.sort_by(|a, b| a.0.cmp(&b.0));
        let stats = SimStats {
            cycles: self.now,
            tasks_run: per_task.iter().map(|(_, s)| s.executed).sum(),
            per_task,
            mem: self.channel.stats.clone(),
            closures_made: self.state.closures_made,
            max_queue_depth: self.max_queue_depth,
            xla_batches: self.xla_batches,
            instrs: self.stack.retired(),
        };
        // End-of-run telemetry: PE utilization + headline counters
        // (no-ops unless --metrics-json).
        crate::obs::metrics::gauge_set("sim.cycles", stats.cycles as f64);
        crate::obs::metrics::counter_set("sim.tasks_run", stats.tasks_run);
        crate::obs::metrics::counter_set("sim.xla_batches", stats.xla_batches);
        crate::obs::metrics::gauge_set("sim.max_queue_depth", stats.max_queue_depth as f64);
        for (name, s) in &stats.per_task {
            crate::obs::metrics::gauge_set(&format!("sim.pe.{name}.utilization"), s.utilization);
        }
        Ok((result, self.state.memory, stats))
    }

    /// Run a task functionally into a (pooled) trace buffer.
    fn trace_into(&mut self, task: &STask) -> Result<Vec<Seg>> {
        let mut trace = self.trace_pool.pop().unwrap_or_default();
        trace.clear();
        let kernels = Arc::clone(&self.kernels);
        exec::trace_task(
            &kernels,
            &self.config.schedule,
            &mut self.state,
            task,
            &mut self.stack,
            &mut trace,
        )?;
        Ok(trace)
    }

    fn dispatch(&mut self, t: u64, fid: FuncId) -> Result<()> {
        loop {
            let group = self.groups[fid.index()].as_mut().expect("PE group for task type");
            // Find a free PE.
            let Some(pe) = group.busy.iter().position(|&b| b <= t) else { return Ok(()) };
            let Some(task) = self.queues[fid.index()].pop_front() else {
                return Ok(());
            };
            let class = group.class;
            match class {
                PeClass::Sequential => {
                    let trace = self.trace_into(&task)?;
                    let group = self.groups[fid.index()].as_mut().expect("PE group for task type");
                    group.busy[pe] = u64::MAX; // released at completion
                    group.stats.executed += 1;
                    let run = self.running.len();
                    self.running.push(Running {
                        task: fid,
                        pe,
                        start: t,
                        trace,
                        idx: 0,
                        done: false,
                    });
                    self.schedule(t, Ev::Step(run));
                    // Sequential PE taken; try to place more tasks on other
                    // PEs in this iteration.
                }
                PeClass::Pipelined { ii } => {
                    let trace = self.trace_into(&task)?;
                    let group = self.groups[fid.index()].as_mut().expect("PE group for task type");
                    group.busy[pe] = t + ii as u64;
                    group.stats.executed += 1;
                    group.stats.busy_cycles += ii as u64;
                    // Issue all loads now; apply effects when compute and
                    // all responses have landed (decoupled: the PE itself
                    // is already free after II).
                    let mut done_at = t;
                    let mut compute = 0u64;
                    for seg in &trace {
                        match seg {
                            Seg::Compute(c) => compute += *c as u64,
                            Seg::Load => {
                                let resp = self.channel.request(t + compute);
                                done_at = done_at.max(resp);
                            }
                            Seg::Effect(_) => {}
                        }
                    }
                    done_at = done_at.max(t + compute);
                    let run = self.running.len();
                    self.running.push(Running {
                        task: fid,
                        pe,
                        start: t,
                        trace,
                        idx: 0,
                        done: false,
                    });
                    self.schedule(done_at, Ev::Apply(run));
                    // Re-arm dispatch when the PE frees.
                    self.schedule(t + ii as u64, Ev::Dispatch(fid));
                }
            }
        }
    }

    /// Advance a sequential task through its trace.
    fn step(&mut self, t: u64, run: usize) -> Result<()> {
        let mut t = t;
        loop {
            let r = &mut self.running[run];
            if r.done {
                return Ok(());
            }
            let Some(seg) = r.trace.get(r.idx) else {
                // Task complete: free the PE, recycle the trace buffer.
                r.done = true;
                let (task, pe, start) = (r.task, r.pe, r.start);
                let trace = std::mem::take(&mut r.trace);
                self.trace_pool.push(trace);
                let group = self.groups[task.index()].as_mut().expect("PE group for task type");
                group.busy[pe] = t;
                group.stats.busy_cycles += t - start;
                self.task_finished();
                self.schedule(t, Ev::Dispatch(task));
                return Ok(());
            };
            let seg = seg.clone();
            r.idx += 1;
            match seg {
                Seg::Compute(c) => {
                    t += c as u64;
                }
                Seg::Load => {
                    // Blocking load: resume at the response.
                    let resp = self.channel.request(t);
                    self.schedule(resp, Ev::Step(run));
                    return Ok(());
                }
                Seg::Effect(e) => self.apply_effect(t, e)?,
            }
        }
    }

    /// Apply all effects of a pipelined task at once.
    fn apply_all(&mut self, t: u64, run: usize) -> Result<()> {
        let trace = std::mem::take(&mut self.running[run].trace);
        for seg in &trace {
            if let Seg::Effect(e) = seg {
                self.apply_effect(t, e.clone())?;
            }
        }
        self.trace_pool.push(trace);
        self.running[run].done = true;
        self.task_finished();
        Ok(())
    }

    fn task_finished(&mut self) {
        debug_assert!(self.pending > 0);
        self.pending -= 1;
    }

    fn apply_effect(&mut self, t: u64, e: Effect) -> Result<()> {
        match e {
            Effect::Spawn(task) => self.enqueue(t, task),
            Effect::ClosureStore { clos, slot, value } => {
                let task = {
                    let c = &self.state.closures[clos];
                    if c.freed {
                        bail!("closure store after fire");
                    }
                    c.task
                };
                let ty = self.kernels.kernel(task).param_tys[slot as usize];
                self.state.closures[clos].slots[slot as usize] = value.coerce(ty);
            }
            Effect::FillDecrement { clos, slot, value } => {
                let task = {
                    let c = &self.state.closures[clos];
                    if c.freed {
                        bail!("send_argument into freed closure");
                    }
                    c.task
                };
                let ty = self.kernels.kernel(task).param_tys[slot as usize];
                self.state.closures[clos].slots[slot as usize] = value.coerce(ty);
                self.decrement(t, clos)?;
            }
            Effect::Decrement { clos } => self.decrement(t, clos)?,
            Effect::RootResult(v) => {
                if self.result.is_some() {
                    bail!("root continuation received two results");
                }
                self.result = Some(v);
            }
        }
        Ok(())
    }

    fn decrement(&mut self, t: u64, clos: usize) -> Result<()> {
        let c = &mut self.state.closures[clos];
        if c.freed {
            bail!("decrement on freed closure");
        }
        if c.counter == 0 {
            bail!("join counter underflow");
        }
        c.counter -= 1;
        if c.counter == 0 {
            c.freed = true;
            self.state.live_closures -= 1;
            let task = STask {
                task: c.task,
                args: ArgList::from_slice(&c.slots),
                cont: c.cont,
            };
            self.enqueue(t, task);
        }
        Ok(())
    }

    /// Flush the XLA batch buffer.
    fn xla_flush(&mut self, t: u64) -> Result<()> {
        self.xla_flush_armed = false;
        if self.xla_buffer.is_empty() {
            return Ok(());
        }
        let t = t.max(self.xla_busy_until);
        let mut batch: Vec<STask> = self
            .xla_buffer
            .drain(..self.xla_buffer.len().min(self.config.xla_batch as usize))
            .collect();
        // Group by task type.
        let mut groups: Vec<(FuncId, Vec<usize>)> = Vec::new();
        for (i, item) in batch.iter().enumerate() {
            match groups.iter_mut().find(|(g, _)| *g == item.task) {
                Some((_, v)) => v.push(i),
                None => groups.push((item.task, vec![i])),
            }
        }
        let latency = self.config.xla_overhead as u64
            + self.config.xla_per_row as u64 * batch.len() as u64;
        let done = t + latency;
        self.xla_busy_until = done;
        self.xla_batches += 1;
        let kernels = Arc::clone(&self.kernels);
        for (fid, idxs) in groups {
            let name = &kernels.kernel(fid).name;
            // Each index belongs to exactly one group: move the args out
            // (same clone-free idiom as the ws runtime's flush).
            let args: Vec<Vec<Value>> = idxs
                .iter()
                .map(|&i| std::mem::take(&mut batch[i].args).into_vec())
                .collect();
            let results = self.xla.exec_batch(name, &args, &mut self.state.memory)?;
            if results.len() != idxs.len() {
                bail!("xla datapath returned {} results for {} rows", results.len(), idxs.len());
            }
            for (&i, value) in idxs.iter().zip(results) {
                self.apply_effect(done, exec::deliver_effect(batch[i].cont, value))?;
                self.task_finished();
            }
        }
        if !self.xla_buffer.is_empty() {
            self.schedule(done, Ev::XlaFlush);
            self.xla_flush_armed = true;
        }
        Ok(())
    }
}
