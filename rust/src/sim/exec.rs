//! Functional task execution → timed trace, driven by compiled kernels.
//!
//! At dispatch the simulator runs the task body functionally (the same
//! kernel bytecode every other engine executes — [`crate::exec`]) and
//! records a *trace*: compute segments (cycles), memory loads (timed by
//! the channel), and effects (spawns, sends, closure ops) at their
//! program positions. The engine then replays the trace against the
//! timing model.
//!
//! Cycle charging comes from the per-instruction [`crate::exec::KCost`]
//! metadata attached at kernel-compile time (mirroring
//! `hls::op_cycles`), resolved against the run's [`ScheduleModel`] —
//! no expression trees are walked during simulation.

use anyhow::{bail, Result};

use crate::exec::{run_kernel, ArgList, KCost, KStack, KernelProgram, KontRef, Machine};
use crate::hls::ScheduleModel;
use crate::interp::Memory;
use crate::ir::cfg::{FuncId, FuncKind, GlobalId};
use crate::ir::expr::Value;

/// Continuation reference (closure handles index the engine's heap).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SCont {
    Root,
    Slot { clos: usize, slot: u32 },
    Counter { clos: usize },
}

/// A simulated closure.
#[derive(Clone, Debug)]
pub struct SClosure {
    pub task: FuncId,
    pub slots: Vec<Value>,
    pub cont: SCont,
    pub counter: u32,
    pub freed: bool,
}

/// A runnable task instance.
#[derive(Clone, Debug)]
pub struct STask {
    pub task: FuncId,
    pub args: ArgList,
    pub cont: SCont,
}

/// One trace element.
#[derive(Clone, Debug)]
pub enum Seg {
    /// Busy datapath cycles.
    Compute(u32),
    /// A memory load (blocking for sequential PEs).
    Load,
    /// Timed effect.
    Effect(Effect),
}

#[derive(Clone, Debug)]
pub enum Effect {
    /// Enqueue a child task.
    Spawn(STask),
    /// Store a ready argument into a closure slot (no counter change).
    ClosureStore { clos: usize, slot: u32, value: Value },
    /// Decrement a closure's counter (close_spawns or void-child return).
    Decrement { clos: usize },
    /// Fill a slot and decrement.
    FillDecrement { clos: usize, slot: u32, value: Value },
    /// Deliver to the root continuation.
    RootResult(Value),
}

/// Mutable functional state shared across the simulation.
pub struct FnState {
    pub memory: Memory,
    pub closures: Vec<SClosure>,
    pub live_closures: usize,
    pub closures_made: u64,
}

impl FnState {
    pub fn alloc_closure(&mut self, c: SClosure) -> usize {
        self.closures_made += 1;
        self.live_closures += 1;
        self.closures.push(c);
        self.closures.len() - 1
    }
}

/// The simulator's [`Machine`]: functional memory reads happen at trace
/// time; task/closure effects are *recorded* (applied later by the
/// engine at their simulated times — counters excepted: the spawner's
/// increment happens-before the child exists, exactly as in the WS
/// runtime).
struct SimMachine<'a> {
    prog: &'a KernelProgram,
    model: &'a ScheduleModel,
    state: &'a mut FnState,
    trace: &'a mut Vec<Seg>,
    cont: SCont,
}

impl<'a> Machine for SimMachine<'a> {
    fn on_dispatch(&mut self, fid: FuncId, _depth: usize) -> Result<()> {
        // Hotness profile: once per frame entry, one relaxed load when off.
        if crate::obs::profile_enabled() {
            crate::obs::profile::hit(&self.prog.kernel(fid).name);
        }
        Ok(())
    }

    #[inline]
    fn charge(&mut self, cost: &KCost) {
        push_compute(self.trace, cost.cycles(self.model));
    }

    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
        let v = self.state.memory.load(arr, index)?;
        self.trace.push(Seg::Load);
        Ok(v)
    }

    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.state.memory.store(arr, index, value)
    }

    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.state.memory.atomic_add(arr, index, value)
    }

    fn make_closure(&mut self, task: FuncId) -> Result<Value> {
        let slots: Vec<Value> = self
            .prog
            .kernel(task)
            .param_tys
            .iter()
            .map(|&t| Value::zero_of(t))
            .collect();
        let handle = self.state.alloc_closure(SClosure {
            task,
            slots,
            cont: self.cont,
            counter: 1,
            freed: false,
        });
        Ok(Value::I64(handle as i64))
    }

    fn closure_store(&mut self, clos: Value, field: u32, value: Value) -> Result<()> {
        self.trace.push(Seg::Effect(Effect::ClosureStore {
            clos: clos.as_i64() as usize,
            slot: field,
            value,
        }));
        Ok(())
    }

    fn spawn_child(&mut self, callee: FuncId, args: &[Value], ret: KontRef) -> Result<()> {
        let cont = match ret {
            KontRef::Slot { clos, field } => {
                let h = clos.as_i64() as usize;
                self.state.closures[h].counter += 1;
                SCont::Slot { clos: h, slot: field }
            }
            KontRef::Counter { clos } => {
                let h = clos.as_i64() as usize;
                self.state.closures[h].counter += 1;
                SCont::Counter { clos: h }
            }
            KontRef::Forward => self.cont,
        };
        self.trace.push(Seg::Effect(Effect::Spawn(STask {
            task: callee,
            args: ArgList::from_slice(args),
            cont,
        })));
        Ok(())
    }

    fn close_spawns(&mut self, clos: Value) -> Result<()> {
        self.trace
            .push(Seg::Effect(Effect::Decrement { clos: clos.as_i64() as usize }));
        Ok(())
    }

    fn send_argument(&mut self, value: Value) -> Result<()> {
        self.trace.push(Seg::Effect(deliver_effect(self.cont, value)));
        Ok(())
    }
}

/// Execute `inst` functionally, appending its trace to `trace` (a
/// caller-owned scratch buffer, recycled across dispatches by the
/// engine's trace pool).
pub fn trace_task(
    prog: &KernelProgram,
    model: &ScheduleModel,
    state: &mut FnState,
    inst: &STask,
    stack: &mut KStack,
    trace: &mut Vec<Seg>,
) -> Result<()> {
    let kind = prog.kernel(inst.task).kind;
    trace.push(Seg::Compute(model.task_read));
    match kind {
        FuncKind::Xla => {
            bail!("xla task `{}` must go to the XLA PE", prog.kernel(inst.task).name)
        }
        FuncKind::Leaf => {
            // A spawned leaf: its body is sequential; loads are timed.
            let cont = inst.cont;
            let mut machine =
                SimMachine { prog, model, state: &mut *state, trace: &mut *trace, cont };
            let value =
                run_kernel(prog, inst.task, inst.args.as_slice(), stack, &mut machine, 50_000_000)?;
            trace.push(Seg::Effect(deliver_effect(cont, value)));
        }
        FuncKind::Task => {
            let mut machine = SimMachine {
                prog,
                model,
                state: &mut *state,
                trace: &mut *trace,
                cont: inst.cont,
            };
            run_kernel(prog, inst.task, inst.args.as_slice(), stack, &mut machine, 50_000_000)?;
        }
    }
    Ok(())
}

pub fn deliver_effect(cont: SCont, value: Value) -> Effect {
    match cont {
        SCont::Root => Effect::RootResult(value),
        SCont::Slot { clos, slot } => Effect::FillDecrement { clos, slot, value },
        SCont::Counter { clos } => Effect::Decrement { clos },
    }
}

pub fn push_compute(trace: &mut Vec<Seg>, cycles: u32) {
    if cycles == 0 {
        return;
    }
    if let Some(Seg::Compute(c)) = trace.last_mut() {
        *c += cycles;
    } else {
        trace.push(Seg::Compute(cycles));
    }
}
