//! Functional task execution → timed trace.
//!
//! At dispatch the simulator runs the task body functionally (same
//! transition rules as the explicit executor) and records a *trace*:
//! compute segments (cycles), memory loads (timed by the channel), and
//! effects (spawns, sends, closure ops) at their program positions. The
//! engine then replays the trace against the timing model.

use anyhow::{bail, Result};

use crate::hls::{op_cycles, ScheduleModel};
use crate::interp::Memory;
use crate::ir::cfg::{FuncId, FuncKind, Module, Op, RetTarget, Term};
use crate::ir::expr::{self, Value, VarId};

/// Continuation reference (closure handles index the engine's heap).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SCont {
    Root,
    Slot { clos: usize, slot: u32 },
    Counter { clos: usize },
}

/// A simulated closure.
#[derive(Clone, Debug)]
pub struct SClosure {
    pub task: FuncId,
    pub slots: Vec<Value>,
    pub cont: SCont,
    pub counter: u32,
    pub freed: bool,
}

/// A runnable task instance.
#[derive(Clone, Debug)]
pub struct STask {
    pub task: FuncId,
    pub args: Vec<Value>,
    pub cont: SCont,
}

/// One trace element.
#[derive(Clone, Debug)]
pub enum Seg {
    /// Busy datapath cycles.
    Compute(u32),
    /// A memory load (blocking for sequential PEs).
    Load,
    /// Timed effect.
    Effect(Effect),
}

#[derive(Clone, Debug)]
pub enum Effect {
    /// Enqueue a child task.
    Spawn(STask),
    /// Store a ready argument into a closure slot (no counter change).
    ClosureStore { clos: usize, slot: u32, value: Value },
    /// Decrement a closure's counter (close_spawns or void-child return).
    Decrement { clos: usize },
    /// Fill a slot and decrement.
    FillDecrement { clos: usize, slot: u32, value: Value },
    /// Deliver to the root continuation.
    RootResult(Value),
}

/// Mutable functional state shared across the simulation.
pub struct FnState {
    pub memory: Memory,
    pub closures: Vec<SClosure>,
    pub live_closures: usize,
    pub closures_made: u64,
}

impl FnState {
    pub fn alloc_closure(&mut self, c: SClosure) -> usize {
        self.closures_made += 1;
        self.live_closures += 1;
        self.closures.push(c);
        self.closures.len() - 1
    }
}

/// Execute `inst` functionally, emitting the trace. Spawned children are
/// created as `STask`s inside `Effect::Spawn`; counters change only when
/// the engine applies effects (timed), keeping join order physical.
pub fn trace_task(
    module: &Module,
    model: &ScheduleModel,
    state: &mut FnState,
    inst: &STask,
) -> Result<Vec<Seg>> {
    let func = &module.funcs[inst.task];
    let mut trace = Vec::new();
    trace.push(Seg::Compute(model.task_read));
    match func.kind {
        FuncKind::Xla => bail!("xla task `{}` must go to the XLA PE", func.name),
        FuncKind::Leaf => {
            // A spawned leaf: its body is sequential; loads are timed.
            let value = eval_body(module, model, state, inst.task, &inst.args, &mut trace)?;
            trace.push(Seg::Effect(deliver_effect(inst.cont, value)));
            return Ok(trace);
        }
        FuncKind::Task => {}
    }
    let cfg = func.cfg();
    if inst.args.len() != func.params {
        bail!("task `{}` arity mismatch", func.name);
    }
    let mut env: Vec<Value> = func.vars.values().map(|v| Value::zero_of(v.ty)).collect();
    for (i, a) in inst.args.iter().enumerate() {
        env[i] = a.coerce(func.vars[VarId::new(i)].ty);
    }
    let mut block = cfg.entry;
    let mut steps = 0u64;
    loop {
        steps += 1;
        if steps > 50_000_000 {
            bail!("task `{}` exceeded step limit", func.name);
        }
        let b = &cfg.blocks[block];
        for op in &b.ops {
            let cycles = op_cycles(model, op);
            match op {
                Op::Assign { dst, src } => {
                    let v = expr::eval(src, &|v| env[v.index()]);
                    env[dst.index()] = v.coerce(func.vars[*dst].ty);
                    push_compute(&mut trace, cycles);
                }
                Op::Load { dst, arr, index, .. } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    env[dst.index()] = state.memory.load(*arr, idx)?;
                    push_compute(&mut trace, cycles);
                    trace.push(Seg::Load);
                }
                Op::Store { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    state.memory.store(*arr, idx, val)?;
                    push_compute(&mut trace, cycles);
                }
                Op::AtomicAdd { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    state.memory.atomic_add(*arr, idx, val)?;
                    push_compute(&mut trace, cycles);
                }
                Op::Call { dst, callee, args } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                    // Inlined leaf body: timed inline (its loads block us).
                    let r = eval_body(module, model, state, *callee, &vals, &mut trace)?;
                    if let Some(d) = dst {
                        env[d.index()] = r.coerce(func.vars[*d].ty);
                    }
                }
                Op::MakeClosure { dst, task } => {
                    let t = &module.funcs[*task];
                    let handle = state.alloc_closure(SClosure {
                        task: *task,
                        slots: t.param_ids().map(|p| Value::zero_of(t.vars[p].ty)).collect(),
                        cont: inst.cont,
                        counter: 1,
                        freed: false,
                    });
                    env[dst.index()] = Value::I64(handle as i64);
                    push_compute(&mut trace, cycles);
                }
                Op::ClosureStore { clos, field, value } => {
                    let h = env[clos.index()].as_i64() as usize;
                    let val = expr::eval(value, &|v| env[v.index()]);
                    push_compute(&mut trace, cycles);
                    trace.push(Seg::Effect(Effect::ClosureStore {
                        clos: h,
                        slot: *field,
                        value: val,
                    }));
                }
                Op::SpawnChild { callee, args, ret } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                    let cont = match ret {
                        RetTarget::Slot { clos, field } => {
                            let h = env[clos.index()].as_i64() as usize;
                            // Counter increments NOW (functionally) — the
                            // spawner's increment happens-before the child
                            // exists, exactly as in the WS runtime.
                            state.closures[h].counter += 1;
                            SCont::Slot { clos: h, slot: *field }
                        }
                        RetTarget::Counter { clos } => {
                            let h = env[clos.index()].as_i64() as usize;
                            state.closures[h].counter += 1;
                            SCont::Counter { clos: h }
                        }
                        RetTarget::Forward => inst.cont,
                    };
                    push_compute(&mut trace, cycles);
                    trace.push(Seg::Effect(Effect::Spawn(STask {
                        task: *callee,
                        args: vals,
                        cont,
                    })));
                }
                Op::CloseSpawns { clos } => {
                    let h = env[clos.index()].as_i64() as usize;
                    push_compute(&mut trace, cycles);
                    trace.push(Seg::Effect(Effect::Decrement { clos: h }));
                }
                Op::SendArgument { value } => {
                    let v = match value {
                        Some(e) => expr::eval(e, &|v| env[v.index()]).coerce(func.ret),
                        None => Value::Unit,
                    };
                    push_compute(&mut trace, cycles);
                    trace.push(Seg::Effect(deliver_effect(inst.cont, v)));
                }
                Op::Spawn { .. } => bail!("implicit Spawn in explicit IR"),
            }
        }
        match &b.term {
            Term::Jump(next) => {
                push_compute(&mut trace, model.branch);
                block = *next;
            }
            Term::Branch { cond, then_, else_ } => {
                push_compute(&mut trace, model.branch);
                let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                block = if c { *then_ } else { *else_ };
            }
            Term::Halt => return Ok(trace),
            other => bail!("terminator {other:?} in explicit task `{}`", func.name),
        }
    }
}

pub fn deliver_effect(cont: SCont, value: Value) -> Effect {
    match cont {
        SCont::Root => Effect::RootResult(value),
        SCont::Slot { clos, slot } => Effect::FillDecrement { clos, slot, value },
        SCont::Counter { clos } => Effect::Decrement { clos },
    }
}

fn push_compute(trace: &mut Vec<Seg>, cycles: u32) {
    if cycles == 0 {
        return;
    }
    if let Some(Seg::Compute(c)) = trace.last_mut() {
        *c += cycles;
    } else {
        trace.push(Seg::Compute(cycles));
    }
}

/// Sequentially evaluate a leaf body, timing its ops into `trace`.
fn eval_body(
    module: &Module,
    model: &ScheduleModel,
    state: &mut FnState,
    fid: FuncId,
    args: &[Value],
    trace: &mut Vec<Seg>,
) -> Result<Value> {
    let func = &module.funcs[fid];
    if func.kind != FuncKind::Leaf {
        bail!("sequential call to non-leaf `{}`", func.name);
    }
    let cfg = func.cfg();
    let mut env: Vec<Value> = func.vars.values().map(|v| Value::zero_of(v.ty)).collect();
    for (i, a) in args.iter().enumerate() {
        env[i] = a.coerce(func.vars[VarId::new(i)].ty);
    }
    let mut block = cfg.entry;
    let mut steps = 0u64;
    loop {
        steps += 1;
        if steps > 50_000_000 {
            bail!("leaf `{}` exceeded step limit", func.name);
        }
        let b = &cfg.blocks[block];
        for op in &b.ops {
            let cycles = op_cycles(model, op);
            match op {
                Op::Assign { dst, src } => {
                    let v = expr::eval(src, &|v| env[v.index()]);
                    env[dst.index()] = v.coerce(func.vars[*dst].ty);
                    push_compute(trace, cycles);
                }
                Op::Load { dst, arr, index, .. } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    env[dst.index()] = state.memory.load(*arr, idx)?;
                    push_compute(trace, cycles);
                    trace.push(Seg::Load);
                }
                Op::Store { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    state.memory.store(*arr, idx, val)?;
                    push_compute(trace, cycles);
                }
                Op::AtomicAdd { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    state.memory.atomic_add(*arr, idx, val)?;
                    push_compute(trace, cycles);
                }
                Op::Call { dst, callee, args } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                    let r = eval_body(module, model, state, *callee, &vals, trace)?;
                    if let Some(d) = dst {
                        env[d.index()] = r.coerce(func.vars[*d].ty);
                    }
                }
                other => bail!("op {other:?} in leaf `{}`", func.name),
            }
        }
        match &b.term {
            Term::Jump(next) => block = *next,
            Term::Branch { cond, then_, else_ } => {
                push_compute(trace, model.branch);
                let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                block = if c { *then_ } else { *else_ };
            }
            Term::Return(value) => {
                return Ok(match value {
                    Some(e) => expr::eval(e, &|v| env[v.index()]).coerce(func.ret),
                    None => Value::Unit,
                })
            }
            other => bail!("terminator {other:?} in leaf `{}`", func.name),
        }
    }
}
