//! Cycle-level simulator of a HardCilk system (the paper's evaluation
//! platform, §III — substituted for the Alveo U55C per DESIGN.md §1.1).
//!
//! Architecture modeled:
//!
//! - **Task queues**: one virtual queue per task type (HardCilk's
//!   work-stealing scheduler with per-type queues; a single queue per type
//!   is the idealized-stealing limit, which is exact for the paper's 1-PE
//!   configurations).
//! - **PEs**: each task type has a configurable number of PEs. A PE runs
//!   the HLS-scheduled task body:
//!   - [`hls::PeClass::Sequential`] PEs interleave compute segments with
//!     *blocking* memory loads (the §II-C limitation);
//!   - [`hls::PeClass::Pipelined`] PEs (DAE access tasks) accept a new
//!     task every II cycles and keep loads outstanding — memory latency is
//!     overlapped across tasks, bounded by the channel.
//! - **Memory channel** ([`channel`]): HBM-like — fixed service latency,
//!   limited outstanding requests, minimum issue interval.
//! - **Scheduler**: dispatch latency per task, spawn-next allocation round
//!   trip, write-buffer issue costs (from [`hls::ScheduleModel`]).
//! - **XLA PE** : `extern xla` tasks execute on a batched datapath
//!   (DESIGN.md §Hardware-Adaptation) with a batch-size-dependent latency.
//!
//! Functional semantics ride along: the simulator *executes* the program
//! (same transition rules as [`crate::interp::explicit_exec`]) while
//! charging cycles, so every simulated run is also checked against the
//! oracle in tests. Functional reads happen at task dispatch; for tree
//! workloads (the paper's dataset) this is exact.

pub mod channel;
pub mod engine;
pub mod exec;

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::hls::ScheduleModel;
use crate::interp::Memory;
use crate::ir::cfg::Module;
use crate::ir::expr::Value;

/// System configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// PEs per task type (by task name); `default_pes` otherwise.
    pub pes: HashMap<String, u32>,
    pub default_pes: u32,
    /// Memory channel: service latency (cycles).
    pub mem_latency: u32,
    /// Maximum outstanding requests.
    pub mem_outstanding: u32,
    /// Minimum cycles between request issues (channel bandwidth).
    pub mem_issue_interval: u32,
    /// Scheduler dispatch latency (queue head → PE start).
    pub dispatch_latency: u32,
    /// Per-op timing model.
    pub schedule: ScheduleModel,
    /// XLA PE: batch size and latency model (overhead + per-row).
    pub xla_batch: u32,
    pub xla_overhead: u32,
    pub xla_per_row: u32,
    /// Clock for time conversions in reports.
    pub freq_mhz: u32,
    /// Safety valve.
    pub max_cycles: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            pes: HashMap::new(),
            default_pes: 1,
            mem_latency: 20,
            mem_outstanding: 8,
            mem_issue_interval: 4,
            dispatch_latency: 12,
            schedule: ScheduleModel::default(),
            xla_batch: 64,
            xla_overhead: 60,
            xla_per_row: 2,
            freq_mhz: 300,
            max_cycles: 50_000_000_000,
        }
    }
}

impl SimConfig {
    /// The paper's §III configurations: one PE in the non-DAE case, one
    /// per task type in the DAE case — which is exactly `default_pes = 1`.
    pub fn paper() -> Self {
        SimConfig::default()
    }

    pub fn with_pes(mut self, task: &str, n: u32) -> Self {
        self.pes.insert(task.to_string(), n);
        self
    }

    pub fn pes_for(&self, task: &str) -> u32 {
        self.pes.get(task).copied().unwrap_or(self.default_pes).max(1)
    }

    pub fn cycles_to_us(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_mhz as f64
    }
}

/// Simulation statistics.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub cycles: u64,
    pub tasks_run: u64,
    pub per_task: Vec<(String, TaskStats)>,
    pub mem: channel::ChannelStats,
    pub closures_made: u64,
    pub max_queue_depth: usize,
    pub xla_batches: u64,
    /// Kernel instructions retired during functional tracing (a fused
    /// superinstruction retires as one dispatch).
    pub instrs: u64,
}

#[derive(Clone, Debug, Default)]
pub struct TaskStats {
    pub executed: u64,
    pub busy_cycles: u64,
    pub pes: u32,
    /// Fraction of total runtime the PEs of this type were busy.
    pub utilization: f64,
}

impl SimStats {
    pub fn task(&self, name: &str) -> Option<&TaskStats> {
        self.per_task.iter().find(|(n, _)| n == name).map(|(_, s)| s)
    }
}

/// Batched XLA datapath used by the simulator (functional part).
pub trait SimXla {
    fn exec_batch(
        &mut self,
        name: &str,
        batch: &[Vec<Value>],
        memory: &mut Memory,
    ) -> Result<Vec<Value>>;
}

/// Rejects xla tasks.
pub struct NoSimXla;

impl SimXla for NoSimXla {
    fn exec_batch(&mut self, name: &str, _b: &[Vec<Value>], _m: &mut Memory) -> Result<Vec<Value>> {
        Err(anyhow!("xla task `{name}` in simulation but no XLA datapath configured"))
    }
}

/// Run the simulator: returns the root result, final memory and stats.
/// Compiles the module's execution kernels on entry — use
/// [`simulate_with_kernels`] (or the session API) to reuse a cached
/// [`crate::exec::KernelProgram`].
pub fn simulate(
    module: &Module,
    memory: Memory,
    entry: &str,
    args: &[Value],
    config: &SimConfig,
    xla: &mut dyn SimXla,
) -> Result<(Value, Memory, SimStats)> {
    engine::Engine::new(module, memory, config, xla)?.run(entry, args)
}

/// [`simulate`] over an already-compiled kernel program.
pub fn simulate_with_kernels(
    module: &Module,
    kernels: std::sync::Arc<crate::exec::KernelProgram>,
    memory: Memory,
    entry: &str,
    args: &[Value],
    config: &SimConfig,
    xla: &mut dyn SimXla,
) -> Result<(Value, Memory, SimStats)> {
    engine::Engine::new_with_kernels(module, kernels, memory, config, xla)?.run(entry, args)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::{bfs, graphgen};

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_simulates_correctly() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mem = Memory::new(m);
        let cfg = SimConfig::default();
        let (v, _, stats) =
            simulate(m, mem, "fib", &[Value::I64(10)], &cfg, &mut NoSimXla).unwrap();
        assert_eq!(v, Value::I64(55));
        assert!(stats.cycles > 0);
        assert_eq!(stats.task("fib").unwrap().executed, 177);
        assert_eq!(stats.task("fib__k1").unwrap().executed, 88);
    }

    #[test]
    fn more_pes_fewer_cycles() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mut cycles = Vec::new();
        for pes in [1u32, 4] {
            let mut cfg = SimConfig::default();
            cfg.default_pes = pes;
            let mem = Memory::new(m);
            let (v, _, stats) =
                simulate(m, mem, "fib", &[Value::I64(12)], &cfg, &mut NoSimXla).unwrap();
            assert_eq!(v, Value::I64(144));
            cycles.push(stats.cycles);
        }
        assert!(
            cycles[1] * 2 < cycles[0],
            "4 PEs should beat 1 PE by >2x on fib: {cycles:?}"
        );
    }

    #[test]
    fn name_keyed_pe_config_resolves_through_funcid_table() {
        // `SimConfig::pes` is keyed by task name, but the engine resolves
        // it once at construction into a FuncId-indexed table; the
        // name-keyed override must still land on exactly its task type.
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let base = {
            let mem = Memory::new(m);
            simulate(m, mem, "fib", &[Value::I64(12)], &SimConfig::default(), &mut NoSimXla)
                .unwrap()
                .2
        };
        let cfg = SimConfig::default().with_pes("fib", 4);
        let mem = Memory::new(m);
        let (v, _, stats) = simulate(m, mem, "fib", &[Value::I64(12)], &cfg, &mut NoSimXla).unwrap();
        assert_eq!(v, Value::I64(144));
        assert_eq!(stats.task("fib").unwrap().pes, 4);
        assert_eq!(stats.task("fib__k1").unwrap().pes, 1, "override must not leak to other tasks");
        assert!(stats.cycles < base.cycles, "4 fib PEs must beat 1: {} vs {}", stats.cycles, base.cycles);
    }

    #[test]
    fn sim_is_deterministic() {
        let r = compile("t", FIB, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let run = || {
            let mem = Memory::new(m);
            simulate(m, mem, "fib", &[Value::I64(11)], &SimConfig::default(), &mut NoSimXla)
                .unwrap()
                .2
                .cycles
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn bfs_tree_visits_all_and_dae_is_faster() {
        let g = graphgen::tree(4, 5); // 341 nodes, quick
        let mut results = Vec::new();
        for (src, opts) in [
            (bfs::BFS_SRC, CompileOptions::no_dae()),
            (bfs::BFS_DAE_SRC, CompileOptions::standard()),
        ] {
            let r = compile("bfs", src, &opts).unwrap();
            let m = &r.explicit;
            let mut mem = Memory::new(m);
            bfs::init_memory(m, &mut mem, &g).unwrap();
            let (_, mem, stats) =
                simulate(m, mem, "visit", &[Value::I64(0)], &SimConfig::paper(), &mut NoSimXla)
                    .unwrap();
            bfs::check_all_visited(m, &mem, &g).unwrap();
            results.push(stats.cycles);
        }
        let (plain, dae) = (results[0], results[1]);
        assert!(
            dae < plain,
            "DAE must reduce runtime: plain={plain} dae={dae}"
        );
        let reduction = 1.0 - dae as f64 / plain as f64;
        // Paper: 26.5% on trees. Accept a generous band here; the bench
        // reports the exact figure on the paper's D=7/D=9 datasets.
        assert!(
            (0.10..0.45).contains(&reduction),
            "reduction {:.1}% out of band (plain={plain}, dae={dae})",
            reduction * 100.0
        );
    }

    #[test]
    fn memory_latency_hurts_non_dae_more() {
        let g = graphgen::tree(4, 4);
        let run = |src: &str, opts: &CompileOptions, lat: u32| {
            let r = compile("bfs", src, opts).unwrap();
            let m = &r.explicit;
            let mut mem = Memory::new(m);
            bfs::init_memory(m, &mut mem, &g).unwrap();
            let mut cfg = SimConfig::paper();
            cfg.mem_latency = lat;
            simulate(m, mem, "visit", &[Value::I64(0)], &cfg, &mut NoSimXla).unwrap().2.cycles
        };
        let plain_slow = run(bfs::BFS_SRC, &CompileOptions::no_dae(), 300);
        let plain_fast = run(bfs::BFS_SRC, &CompileOptions::no_dae(), 40);
        let dae_slow = run(bfs::BFS_DAE_SRC, &CompileOptions::standard(), 300);
        let dae_fast = run(bfs::BFS_DAE_SRC, &CompileOptions::standard(), 40);
        let plain_ratio = plain_slow as f64 / plain_fast as f64;
        let dae_ratio = dae_slow as f64 / dae_fast as f64;
        assert!(
            plain_ratio > dae_ratio,
            "latency sensitivity: plain {plain_ratio:.2}x vs dae {dae_ratio:.2}x"
        );
    }
}

#[cfg(test)]
mod calib {
    use super::*;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::{bfs, graphgen};

    #[test]
    fn dae_reduction_calibration() {
        let g = graphgen::paper_tree_small();
        let mut res = Vec::new();
        for (src, opts) in [
            (bfs::BFS_SRC, CompileOptions::no_dae()),
            (bfs::BFS_DAE_SRC, CompileOptions::standard()),
        ] {
            let r = compile("bfs", src, &opts).unwrap();
            let m = &r.explicit;
            let mut mem = Memory::new(m);
            bfs::init_memory(m, &mut mem, &g).unwrap();
            let (_, _, stats) =
                simulate(m, mem, "visit", &[Value::I64(0)], &SimConfig::paper(), &mut NoSimXla)
                    .unwrap();
            res.push(stats.cycles);
        }
        eprintln!(
            "D=7: plain={} dae={} reduction={:.1}%",
            res[0],
            res[1],
            (1.0 - res[1] as f64 / res[0] as f64) * 100.0
        );
        // Paper: 26.5% overall. Guard the calibrated band tightly here.
        let reduction = 1.0 - res[1] as f64 / res[0] as f64;
        assert!((0.20..0.33).contains(&reduction), "calibration drifted: {reduction:.3}");
    }
}
