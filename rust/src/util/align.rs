//! Bit/byte alignment helpers used by closure layout (paper §II-B: closures
//! must be padded to hardware-friendly power-of-two widths, 128/256-bit...).

/// Round `value` up to the next multiple of `align`. `align` must be > 0.
#[inline]
pub fn round_up(value: u32, align: u32) -> u32 {
    assert!(align > 0);
    value.div_ceil(align) * align
}

/// Round `bits` up to the next power-of-two bucket that is at least
/// `min_bits`, capped at `max_bits`. This is the HardCilk closure-width rule:
/// a closure occupies a power-of-two number of bits (128, 256, 512, ...)
/// so the on-chip queues and the memory interface can address it trivially.
pub fn pow2_bucket(bits: u32, min_bits: u32, max_bits: u32) -> u32 {
    assert!(min_bits.is_power_of_two() && max_bits.is_power_of_two());
    let mut bucket = min_bits;
    while bucket < bits {
        bucket *= 2;
        assert!(
            bucket <= max_bits,
            "closure of {bits} bits exceeds maximum supported width {max_bits}"
        );
    }
    bucket
}

/// True if `value` is a multiple of `align`.
#[inline]
pub fn is_aligned(value: u32, align: u32) -> bool {
    value % align == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn pow2_buckets() {
        assert_eq!(pow2_bucket(0, 128, 1024), 128);
        assert_eq!(pow2_bucket(128, 128, 1024), 128);
        assert_eq!(pow2_bucket(129, 128, 1024), 256);
        assert_eq!(pow2_bucket(300, 128, 1024), 512);
        assert_eq!(pow2_bucket(1024, 128, 1024), 1024);
    }

    #[test]
    #[should_panic]
    fn pow2_bucket_overflow_panics() {
        pow2_bucket(2048, 128, 1024);
    }
}
