//! Micro/macro benchmark harness (criterion is not available offline).
//!
//! Each `rust/benches/*.rs` target sets `harness = false` and drives this:
//! warmup, N timed iterations, median/mean/min/max/stddev reporting, and an
//! optional throughput figure. Output is stable plain text so `cargo bench`
//! logs can be diffed and pasted into EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl Stats {
    fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let median = samples[n / 2];
        let mean_ns = mean.as_nanos() as f64;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_nanos() as f64 - mean_ns;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median,
            min: samples[0],
            max: samples[n - 1],
            stddev: Duration::from_nanos(var.sqrt() as u64),
        }
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Run `f` with warmup then timed samples; prints a one-line summary.
/// Returns the stats so benches can compute derived figures (ratios etc.).
pub fn bench<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> Stats {
    assert!(samples > 0);
    // Warmup: at least one run, at most ~0.5 s.
    let warm_start = Instant::now();
    let mut warm_iters = 0;
    while warm_iters < 2 || (warm_start.elapsed() < Duration::from_millis(200) && warm_iters < 20)
    {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    let stats = Stats::from_samples(times);
    println!(
        "bench {name:<42} median {:>10}  mean {:>10}  min {:>10}  max {:>10}  (n={})",
        fmt_duration(stats.median),
        fmt_duration(stats.mean),
        fmt_duration(stats.min),
        fmt_duration(stats.max),
        stats.iters,
    );
    stats
}

/// Print a throughput line derived from a stats record.
pub fn throughput(name: &str, stats: &Stats, items: u64, unit: &str) {
    let per_sec = items as f64 / stats.median.as_secs_f64();
    let formatted = if per_sec >= 1e9 {
        format!("{:.2} G{unit}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{unit}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k{unit}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} {unit}/s")
    };
    println!("bench {name:<42} throughput {formatted} ({items} {unit} / median run)");
}

/// Render the pass manager's per-pass timings as a markdown-pipe table.
/// Used by the `compile_time` bench and `bombyx compile --timings`.
pub fn timing_table(timings: &[crate::lower::PassTiming]) -> String {
    let mut table = super::table::Table::new(["pass", "time", "funcs", "status"]);
    for t in timings {
        table.row([
            t.pass.to_string(),
            if t.ran { fmt_duration(t.duration) } else { "-".to_string() },
            if t.ran { t.funcs.to_string() } else { "-".to_string() },
            if t.ran { "ran".to_string() } else { "skipped".to_string() },
        ]);
    }
    table.render()
}

/// Standard header for a bench binary; prints build mode so logs are
/// self-describing.
pub fn banner(bench_name: &str, what: &str) {
    let mode = if cfg!(debug_assertions) { "debug" } else { "release" };
    println!("=== bombyx bench: {bench_name} [{mode}] ===");
    println!("{what}");
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats::from_samples(vec![
            Duration::from_nanos(10),
            Duration::from_nanos(20),
            Duration::from_nanos(30),
        ]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.mean, Duration::from_nanos(20));
        assert_eq!(s.median, Duration::from_nanos(20));
        assert_eq!(s.min, Duration::from_nanos(10));
        assert_eq!(s.max, Duration::from_nanos(30));
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_nanos(5)), "5 ns");
        assert_eq!(fmt_duration(Duration::from_micros(5)), "5.00 us");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00 ms");
        assert_eq!(fmt_duration(Duration::from_secs(5)), "5.000 s");
    }

    #[test]
    fn timing_table_renders_skips() {
        use crate::lower::PassTiming;
        let rows = [
            PassTiming {
                pass: "ast_to_cfg",
                duration: Duration::from_micros(12),
                ran: true,
                funcs: 3,
            },
            PassTiming { pass: "dae", duration: Duration::ZERO, ran: false, funcs: 0 },
        ];
        let t = timing_table(&rows);
        assert!(t.contains("ast_to_cfg"), "{t}");
        assert!(t.contains("12.00 us"), "{t}");
        assert!(t.contains("skipped"), "{t}");
    }

    #[test]
    fn bench_runs_function() {
        let mut count = 0u32;
        let stats = bench("test_fn", 3, || {
            count += 1;
            count
        });
        assert_eq!(stats.iters, 3);
        assert!(count >= 5); // warmup + samples
    }
}
