//! Golden-file comparison for test harnesses.
//!
//! Policy (snapshot-on-write with an opt-in strict mode):
//!
//! - missing golden, or `BOMBYX_UPDATE_GOLDENS=1` → the golden is
//!   (re)written from the actual output and the check passes ("blessed");
//!   in strict mode a *missing* golden is a failure instead — otherwise a
//!   fresh checkout would self-bless and the strict run would be vacuous;
//! - golden present and equal → pass;
//! - golden present and different → the actual output is written next to
//!   the golden as `<name>.new` with a diff preview on stderr; the check
//!   **fails** only when `BOMBYX_STRICT_GOLDENS=1` is set (CI sets it),
//!   so a stale golden never breaks a plain local `cargo test` — the
//!   `.new` file and the warning are the signal to re-bless.
//!
//! Goldens live under the crate root; paths are relative to
//! `CARGO_MANIFEST_DIR` so the harness works from any working directory.

use std::path::PathBuf;

/// Outcome of one golden comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GoldenStatus {
    Matched,
    Blessed,
    Mismatched,
}

fn manifest_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// Compare `actual` against the golden at `rel_path` (relative to the
/// crate root) under the policy above. Panics on mismatch in strict mode.
pub fn check_golden(rel_path: &str, actual: &str) -> GoldenStatus {
    let path = manifest_path(rel_path);
    let update = std::env::var_os("BOMBYX_UPDATE_GOLDENS").is_some();
    let strict = std::env::var_os("BOMBYX_STRICT_GOLDENS").is_some();
    let existing = std::fs::read_to_string(&path).ok();
    match existing {
        Some(golden) if golden == actual && !update => GoldenStatus::Matched,
        Some(golden) if !update => {
            let new_path = path.with_extension(format!(
                "{}.new",
                path.extension().and_then(|e| e.to_str()).unwrap_or("txt")
            ));
            let _ = std::fs::write(&new_path, actual);
            let diff = first_diff(&golden, actual);
            let msg = format!(
                "golden mismatch: {rel_path}\n  {diff}\n  actual written to {}\n  \
                 re-bless with BOMBYX_UPDATE_GOLDENS=1",
                new_path.display()
            );
            if strict {
                panic!("{msg}");
            }
            eprintln!("WARNING: {msg}");
            GoldenStatus::Mismatched
        }
        _ => {
            if strict && !update {
                panic!(
                    "golden missing in strict mode: {rel_path}\n  \
                     bless it locally (plain `cargo test` or BOMBYX_UPDATE_GOLDENS=1) \
                     and commit the file"
                );
            }
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent).expect("create golden directory");
            }
            std::fs::write(&path, actual)
                .unwrap_or_else(|e| panic!("writing golden {rel_path}: {e}"));
            eprintln!("blessed golden: {rel_path}");
            GoldenStatus::Blessed
        }
    }
}

fn first_diff(golden: &str, actual: &str) -> String {
    for (i, (g, a)) in golden.lines().zip(actual.lines()).enumerate() {
        if g != a {
            return format!("first difference at line {}:\n  - {g}\n  + {a}", i + 1);
        }
    }
    format!(
        "line counts differ: golden {} vs actual {}",
        golden.lines().count(),
        actual.lines().count()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bless_then_match_roundtrip() {
        if std::env::var_os("BOMBYX_STRICT_GOLDENS").is_some() {
            // Strict mode fails on missing goldens by design; the bless
            // flow is a non-strict workflow.
            return;
        }
        let rel = format!("target/golden_test_{}.txt", std::process::id());
        let _ = std::fs::remove_file(manifest_path(&rel));
        assert_eq!(check_golden(&rel, "hello\n"), GoldenStatus::Blessed);
        assert_eq!(check_golden(&rel, "hello\n"), GoldenStatus::Matched);
        // Default (non-strict) mode reports but does not panic.
        assert_eq!(check_golden(&rel, "changed\n"), GoldenStatus::Mismatched);
        let _ = std::fs::remove_file(manifest_path(&rel).with_extension("txt.new"));
        let _ = std::fs::remove_file(manifest_path(&rel));
    }

    #[test]
    fn first_diff_pinpoints_line() {
        let d = first_diff("a\nb\nc\n", "a\nX\nc\n");
        assert!(d.contains("line 2"), "{d}");
    }
}
