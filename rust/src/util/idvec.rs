//! Typed index vectors: arena-style storage addressed by strongly-typed ids.
//!
//! Compiler IRs in this crate never hold references between entities; they
//! hold `Id`s into `IdVec`s, which keeps the IR `Clone`, serializable and
//! free of lifetime entanglement.

use std::fmt;
use std::marker::PhantomData;

/// A strongly-typed index. `T` is a phantom tag type.
pub struct Id<T> {
    raw: u32,
    _tag: PhantomData<fn() -> T>,
}

impl<T> Id<T> {
    #[inline]
    pub fn new(raw: usize) -> Self {
        debug_assert!(raw <= u32::MAX as usize);
        Id { raw: raw as u32, _tag: PhantomData }
    }

    #[inline]
    pub fn index(self) -> usize {
        self.raw as usize
    }
}

impl<T> Default for Id<T> {
    fn default() -> Self {
        Id::new(0)
    }
}

impl<T> Copy for Id<T> {}
impl<T> Clone for Id<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> PartialEq for Id<T> {
    fn eq(&self, other: &Self) -> bool {
        self.raw == other.raw
    }
}
impl<T> Eq for Id<T> {}
impl<T> PartialOrd for Id<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Id<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.raw.cmp(&other.raw)
    }
}
impl<T> std::hash::Hash for Id<T> {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.raw.hash(state)
    }
}
impl<T> fmt::Debug for Id<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.raw)
    }
}
impl<T> fmt::Display for Id<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.raw)
    }
}

/// Growable storage addressed by `Id<T>`-compatible tags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IdVec<T> {
    items: Vec<T>,
}

impl<T> Default for IdVec<T> {
    fn default() -> Self {
        IdVec { items: Vec::new() }
    }
}

impl<T> IdVec<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        IdVec { items: Vec::with_capacity(cap) }
    }

    pub fn push(&mut self, item: T) -> Id<T> {
        let id = Id::new(self.items.len());
        self.items.push(item);
        id
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn ids(&self) -> impl Iterator<Item = Id<T>> + '_ {
        (0..self.items.len()).map(Id::new)
    }

    pub fn iter(&self) -> impl Iterator<Item = (Id<T>, &T)> {
        self.items.iter().enumerate().map(|(i, t)| (Id::new(i), t))
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (Id<T>, &mut T)> {
        self.items.iter_mut().enumerate().map(|(i, t)| (Id::new(i), t))
    }

    pub fn values(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }
}

impl<T> std::ops::Index<Id<T>> for IdVec<T> {
    type Output = T;
    #[inline]
    fn index(&self, id: Id<T>) -> &T {
        &self.items[id.index()]
    }
}

impl<T> std::ops::IndexMut<Id<T>> for IdVec<T> {
    #[inline]
    fn index_mut(&mut self, id: Id<T>) -> &mut T {
        &mut self.items[id.index()]
    }
}

/// Dense per-id side table with a default value.
#[derive(Clone, Debug)]
pub struct IdMap<T, V> {
    items: Vec<V>,
    _tag: PhantomData<fn() -> T>,
}

impl<T, V: Clone + Default> IdMap<T, V> {
    pub fn with_len(len: usize) -> Self {
        IdMap { items: vec![V::default(); len], _tag: PhantomData }
    }
}

impl<T, V> std::ops::Index<Id<T>> for IdMap<T, V> {
    type Output = V;
    #[inline]
    fn index(&self, id: Id<T>) -> &V {
        &self.items[id.index()]
    }
}

impl<T, V> std::ops::IndexMut<Id<T>> for IdMap<T, V> {
    #[inline]
    fn index_mut(&mut self, id: Id<T>) -> &mut V {
        &mut self.items[id.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Tag;

    #[test]
    fn push_and_index() {
        let mut v: IdVec<&'static str> = IdVec::new();
        let a = v.push("a");
        let b = v.push("b");
        assert_eq!(v[a], "a");
        assert_eq!(v[b], "b");
        assert_eq!(v.len(), 2);
        assert_ne!(a, b);
    }

    #[test]
    fn ids_are_stable_and_ordered() {
        let mut v: IdVec<u32> = IdVec::new();
        let ids: Vec<_> = (0..10).map(|i| v.push(i)).collect();
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(id.index(), i);
            assert_eq!(v[*id], i as u32);
        }
        let collected: Vec<_> = v.ids().collect();
        assert_eq!(collected, ids);
    }

    #[test]
    fn idmap_defaults() {
        let mut v: IdVec<u8> = IdVec::new();
        let a = v.push(1);
        let mut m: IdMap<u8, u64> = IdMap::with_len(v.len());
        assert_eq!(m[a], 0);
        m[a] = 7;
        assert_eq!(m[a], 7);
    }

    #[test]
    fn id_hash_eq() {
        use std::collections::HashSet;
        let mut s: HashSet<Id<Tag>> = HashSet::new();
        s.insert(Id::new(3));
        assert!(s.contains(&Id::new(3)));
        assert!(!s.contains(&Id::new(4)));
    }
}
