//! Minimal JSON document model + pretty serializer (+ a small parser used by
//! tests to validate emitted descriptors round-trip).
//!
//! serde/serde_json are not in the offline vendor set; the HardCilk system
//! descriptor (paper §II-B) is emitted through this module.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic and goldens are stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn object() -> Json {
        Json::Object(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Object(map) = self {
            map.insert(key.to_string(), value.into());
        } else {
            panic!(
                "Json::set(\"{key}\") called on a non-object value: {} — build the node with \
                 Json::object() first",
                self.pretty()
            );
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line serialization with no whitespace — for wire protocols
    /// and one-record-per-line logs (the serve daemon's framing and
    /// request log). Parses back to the same value as [`Json::pretty`].
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
            // Scalars serialize identically in both modes.
            other => other.write(out, 0),
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Float(v) => {
                // JSON has no NaN/Infinity literals; a non-finite float
                // must degrade to null or the document won't parse.
                if !v.is_finite() {
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{v:.1}");
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Object(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Int(v as i64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Float(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Array(v)
    }
}

/// Parse a JSON document (used by tests to check descriptor round-trips).
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing characters at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    if *pos >= bytes.len() {
        return Err("unexpected end of input".into());
    }
    match bytes[*pos] {
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Object(map));
            }
            loop {
                skip_ws(bytes, pos);
                let key = match parse_value(bytes, pos)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key must be string, got {other:?}")),
                };
                skip_ws(bytes, pos);
                if bytes.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let value = parse_value(bytes, pos)?;
                map.insert(key, value);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Object(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Array(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        b'"' => parse_string(bytes, pos).map(Json::Str),
        b't' => expect_lit(bytes, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect_lit(bytes, pos, "false").map(|_| Json::Bool(false)),
        b'n' => expect_lit(bytes, pos, "null").map(|_| Json::Null),
        _ => parse_number(bytes, pos),
    }
}

fn expect_lit(bytes: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected '{lit}' at byte {pos}"))
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(bytes[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&bytes[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).ok_or("bad codepoint")?);
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar.
                let rest = std::str::from_utf8(&bytes[*pos..]).map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let mut is_float = false;
    while let Some(&b) = bytes.get(*pos) {
        match b {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'+' | b'-' => {
                is_float = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?;
    if is_float {
        text.parse::<f64>().map(Json::Float).map_err(|e| e.to_string())
    } else {
        text.parse::<i64>().map(Json::Int).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut doc = Json::object();
        doc.set("name", "fib");
        doc.set("width", 128i64);
        doc.set("tasks", Json::Array(vec![Json::from("fib"), Json::from("sum")]));
        let text = doc.pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn compact_is_one_line_and_roundtrips() {
        let mut doc = Json::object();
        doc.set("a", Json::Array(vec![Json::Int(1), Json::Str("x\ny".into())]));
        doc.set("b", true);
        doc.set("empty", Json::object());
        let text = doc.compact();
        assert!(!text.contains('\n'), "compact output must be one line: {text}");
        assert!(!text.contains(": "), "no pretty separators: {text}");
        assert_eq!(parse(&text).unwrap(), doc);
        assert_eq!(parse(&doc.pretty()).unwrap(), parse(&text).unwrap());
    }

    #[test]
    fn escapes() {
        let doc = Json::Str("a\"b\\c\nd".into());
        let text = doc.pretty();
        assert_eq!(text, r#""a\"b\\c\nd""#);
        assert_eq!(parse(&text).unwrap(), doc);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#" { "a": [1, 2.5, {"b": null}], "c": true } "#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(true)));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[0], Json::Int(1));
        assert_eq!(arr[1], Json::Float(2.5));
    }

    #[test]
    fn deterministic_key_order() {
        let mut a = Json::object();
        a.set("z", 1i64);
        a.set("a", 2i64);
        let mut b = Json::object();
        b.set("a", 2i64);
        b.set("z", 1i64);
        assert_eq!(a.pretty(), b.pretty());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        assert_eq!(parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(parse("-4.5").unwrap(), Json::Float(-4.5));
    }

    #[test]
    fn non_finite_floats_emit_null() {
        assert_eq!(Json::Float(f64::NAN).pretty(), "null");
        assert_eq!(Json::Float(f64::INFINITY).pretty(), "null");
        assert_eq!(Json::Float(f64::NEG_INFINITY).pretty(), "null");
        // The emitted document must stay parseable.
        let mut doc = Json::object();
        doc.set("bad", f64::NAN);
        assert!(parse(&doc.pretty()).is_ok());
    }
}
