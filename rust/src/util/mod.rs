//! Dependency-free utility substrates.
//!
//! The offline vendor set for this environment contains only `xla` and
//! `anyhow`; every other substrate a project like this normally pulls from
//! crates.io (JSON emission, RNG, property testing, bench timing, table
//! pretty-printing) is implemented here from scratch.

pub mod align;
pub mod bench;
pub mod golden;
pub mod idvec;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod table;
