//! Scoped-thread sharding: the one parallelism idiom the codebase uses.
//!
//! Extracted from `coordinator::driver::BfsExperiment::run_grid` (PR 2's
//! sweep sharding) so the batch compiler, the sweep benches and any future
//! fan-out share a single, tested implementation instead of re-deriving
//! the chunking arithmetic. No work-stealing, no channels: contiguous
//! chunks over `std::thread::scope`, results returned in input order.

/// Number of workers to use for `n` independent items: one per available
/// core, capped at the item count, at least 1.
pub fn default_workers(n: usize) -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n.max(1))
}

/// Apply `f` to every item, sharded across `workers` OS threads with
/// `std::thread::scope`. Results come back in `items` order. `workers`
/// is clamped to `[1, items.len()]` and exactly that many threads are
/// spawned, over balanced contiguous chunks whose sizes differ by at
/// most one (naive `div_ceil` chunking can leave workers idle — 6 items
/// on 4 workers must split 2/2/1/1, not 2/2/2). With one worker the
/// items run on the calling thread (no spawn overhead for the serial
/// case).
pub fn shard_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let workers = workers.max(1).min(items.len());
    if workers == 1 {
        return items.iter().map(&f).collect();
    }
    let base = items.len() / workers;
    let extra = items.len() % workers;
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(items.len(), || None);
    let f = &f;
    std::thread::scope(|scope| {
        let mut items_rest: &[T] = items;
        let mut slots_rest: &mut [Option<R>] = &mut slots;
        for w in 0..workers {
            let take = base + usize::from(w < extra);
            let (chunk_items, next_items) = items_rest.split_at(take);
            let rest_now = std::mem::take(&mut slots_rest);
            let (outs, next_slots) = rest_now.split_at_mut(take);
            items_rest = next_items;
            slots_rest = next_slots;
            scope.spawn(move || {
                for (item, out) in chunk_items.iter().zip(outs.iter_mut()) {
                    *out = Some(f(item));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.expect("every shard slot is filled by its worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..23).collect();
        for workers in [1, 2, 4, 23, 64] {
            let out = shard_map(&items, workers, |&i| i * 2);
            assert_eq!(out, items.iter().map(|i| i * 2).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn empty_input_is_empty_output() {
        let out: Vec<u32> = shard_map(&[] as &[u32], 4, |&i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn workers_clamped_to_item_count() {
        let out = shard_map(&[1, 2], 16, |&i| i + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers(0) >= 1);
        assert!(default_workers(100) >= 1);
    }
}
