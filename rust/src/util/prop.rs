//! Minimal property-based testing harness (proptest is not available
//! offline). Provides seeded case generation with shrinking over integer
//! vectors, which is what our invariants need: random programs, random
//! workloads, random scheduler interleavings.
//!
//! Usage:
//! ```ignore
//! prop_check("closure width is pow2", 500, |g| {
//!     let nfields = g.usize_in(0, 12);
//!     ...
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Generator handle passed to property bodies.
pub struct Gen {
    rng: Rng,
    /// Trace of raw choices (for reporting a reproducible case).
    trace: Vec<u64>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn u64_below(&mut self, bound: u64) -> u64 {
        let v = self.rng.below(bound);
        self.trace.push(v);
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.u64_below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.rng.range_i64(lo, hi);
        self.trace.push(v as u64);
        v
    }

    pub fn bool(&mut self) -> bool {
        self.u64_below(2) == 1
    }

    pub fn f32_unit(&mut self) -> f32 {
        let v = self.rng.unit_f32();
        self.trace.push(v.to_bits() as u64);
        v
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let idx = self.usize_in(0, items.len() - 1);
        &items[idx]
    }

    /// A vector of integers in `[lo, hi]` of length in `[0, max_len]`.
    pub fn vec_i64(&mut self, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| self.i64_in(lo, hi)).collect()
    }
}

/// Result of a single property case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `body`. Panics (with the failing seed) on the
/// first failure. The base seed is fixed for reproducibility but can be
/// overridden with the BOMBYX_PROP_SEED environment variable.
pub fn prop_check(name: &str, cases: u64, mut body: impl FnMut(&mut Gen) -> PropResult) {
    let base_seed: u64 = std::env::var("BOMBYX_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xB0B1_C0DE);
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n  \
                 rerun with BOMBYX_PROP_SEED={base_seed} to reproduce"
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert equality with a readable message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{} != {} ({})", format!("{:?}", a), format!("{:?}", b), format!($($fmt)+)));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("trivial", 50, |g| {
            count += 1;
            let v = g.usize_in(0, 10);
            if v <= 10 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'must_fail' failed")]
    fn failing_property_panics_with_seed() {
        prop_check("must_fail", 10, |g| {
            let v = g.usize_in(0, 100);
            if v < 1000 {
                Err(format!("forced failure, v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_is_seed_deterministic() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        for _ in 0..20 {
            assert_eq!(a.u64_below(1000), b.u64_below(1000));
        }
    }

    #[test]
    fn vec_gen_bounds() {
        let mut g = Gen::new(3);
        for _ in 0..100 {
            let v = g.vec_i64(8, -5, 5);
            assert!(v.len() <= 8);
            assert!(v.iter().all(|x| (-5..=5).contains(x)));
        }
    }
}
