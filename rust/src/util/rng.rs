//! Deterministic PRNG (xorshift64* / splitmix64).
//!
//! No `rand` crate is available offline; simulators, workload generators and
//! the property-testing harness all seed from this. Determinism is a feature:
//! every experiment in EXPERIMENTS.md records its seed.

/// splitmix64 — used to expand a user seed into a well-mixed state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xorshift64* generator. Small, fast, good enough for workload synthesis.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Never allow the all-zero state.
        let mut s = seed;
        let state = splitmix64(&mut s) | 1;
        Rng { state }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. Debiased via rejection on the top bits.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        self.unit_f64() as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Fork a decorrelated child generator (for per-worker seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit: {seen:?}");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            hit_lo |= v == -3;
            hit_hi |= v == 3;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn unit_f64_in_range_and_mean_reasonable() {
        let mut r = Rng::new(11);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean = {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
