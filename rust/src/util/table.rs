//! Plain-text table rendering for bench/report output (the Fig. 6 table,
//! sweeps, EXPERIMENTS.md blocks). Markdown-pipe style so output can be
//! pasted into docs verbatim.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: impl IntoIterator<Item = S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (cell, w) in cells.iter().zip(widths) {
                line.push(' ');
                line.push_str(cell);
                line.push_str(&" ".repeat(w - cell.len() + 1));
                line.push('|');
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a count with thousands separators: 87381 -> "87,381".
pub fn commas(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::new();
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Format a ratio as a signed percentage: 1.47 -> "+47.0%".
pub fn pct_delta(ratio: f64) -> String {
    format!("{:+.1}%", (ratio - 1.0) * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "LUT"]);
        t.row(["Non-DAE", "2657"]);
        t.row(["Spawner", "133"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].contains("2657"));
        // All lines same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn commas_grouping() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(5461), "5,461");
        assert_eq!(commas(87381), "87,381");
        assert_eq!(commas(1234567), "1,234,567");
    }

    #[test]
    fn pct() {
        assert_eq!(pct_delta(1.47), "+47.0%");
        assert_eq!(pct_delta(0.735), "-26.5%");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
