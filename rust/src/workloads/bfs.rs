//! The paper's flagship benchmark (§III): parallel breadth-first graph
//! traversal, with and without the DAE pragma (Fig. 5).

use anyhow::Result;

use crate::interp::Memory;
use crate::ir::cfg::Module;

use super::graphgen::CsrGraph;

/// Fig. 5 workload, CSR form. `visit` loads the node's adjacency range
/// (the "structure representing the adjacency list"), marks the node
/// visited, then recursively visits children in parallel.
pub const BFS_SRC: &str = "\
global int adj_off[];
global int adj_edges[];
global int visited[];

void visit(int n) {
    int off = adj_off[n];
    int end = adj_off[n + 1];
    visited[n] = 1;
    for (int i = off; i < end; i = i + 1) {
        cilk_spawn visit(adj_edges[i]);
    }
    cilk_sync;
}
";

/// Same program with `#pragma bombyx dae` on the adjacency loads (the
/// paper inserts the pragma \"on line 2 to separate the memory access for
/// the adjacency list into its own access task\").
pub const BFS_DAE_SRC: &str = "\
global int adj_off[];
global int adj_edges[];
global int visited[];

void visit(int n) {
    #pragma bombyx dae
    int off = adj_off[n];
    #pragma bombyx dae
    int end = adj_off[n + 1];
    visited[n] = 1;
    for (int i = off; i < end; i = i + 1) {
        cilk_spawn visit(adj_edges[i]);
    }
    cilk_sync;
}
";

/// Seed a memory image with the graph.
pub fn init_memory(module: &Module, memory: &mut Memory, graph: &CsrGraph) -> Result<()> {
    memory.fill_i64(
        module
            .global_by_name("adj_off")
            .ok_or_else(|| anyhow::anyhow!("no adj_off"))?,
        &graph.adj_off,
    );
    memory.fill_i64(
        module
            .global_by_name("adj_edges")
            .ok_or_else(|| anyhow::anyhow!("no adj_edges"))?,
        &graph.adj_edges,
    );
    memory.resize_by_name(module, "visited", graph.nodes())?;
    Ok(())
}

/// All nodes reachable from 0 must be marked (for our generators: all).
pub fn check_all_visited(module: &Module, memory: &Memory, graph: &CsrGraph) -> Result<()> {
    let visited =
        memory.dump_i64(module.global_by_name("visited").ok_or_else(|| anyhow::anyhow!("no visited"))?);
    let unvisited = visited.iter().filter(|&&v| v == 0).count();
    if unvisited != 0 {
        anyhow::bail!("{unvisited}/{} nodes unvisited", graph.nodes());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::oracle::run_oracle;
    use crate::ir::expr::Value;
    use crate::lower::{compile, CompileOptions};
    use crate::workloads::graphgen;

    #[test]
    fn oracle_visits_whole_paper_tree_small() {
        let r = compile("bfs", BFS_SRC, &CompileOptions::no_dae()).unwrap();
        let g = graphgen::paper_tree_small();
        let mut mem = Memory::new(&r.implicit);
        init_memory(&r.implicit, &mut mem, &g).unwrap();
        let (_, mem) = run_oracle(&r.implicit, mem, "visit", &[Value::I64(0)]).unwrap();
        check_all_visited(&r.implicit, &mem, &g).unwrap();
    }

    #[test]
    fn dae_and_plain_agree_on_random_dag() {
        let g = graphgen::random_dag(500, 2.5, 11);
        let mut images = Vec::new();
        for (src, opts) in
            [(BFS_SRC, CompileOptions::no_dae()), (BFS_DAE_SRC, CompileOptions::standard())]
        {
            let r = compile("bfs", src, &opts).unwrap();
            let mut mem = Memory::new(&r.implicit);
            init_memory(&r.implicit, &mut mem, &g).unwrap();
            let (_, mem) = run_oracle(&r.implicit, mem, "visit", &[Value::I64(0)]).unwrap();
            images.push(mem.dump_i64(r.implicit.global_by_name("visited").unwrap()));
        }
        assert_eq!(images[0], images[1]);
    }
}
