//! Fibonacci — the paper's running example (Fig. 1 / Fig. 2).

/// Fig. 1, verbatim modulo Cilk-C surface syntax.
pub const FIB_SRC: &str = "\
int fib(int n) {
    if (n < 2)
        return n;
    int x = cilk_spawn fib(n - 1);
    int y = cilk_spawn fib(n - 2);
    cilk_sync;
    return x + y;
}
";

/// Reference values.
pub fn fib_ref(n: u64) -> u64 {
    let (mut a, mut b) = (0u64, 1u64);
    for _ in 0..n {
        let c = a + b;
        a = b;
        b = c;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_series() {
        let expect = [0, 1, 1, 2, 3, 5, 8, 13, 21, 34, 55];
        for (n, &e) in expect.iter().enumerate() {
            assert_eq!(fib_ref(n as u64), e);
        }
        assert_eq!(fib_ref(30), 832_040);
    }
}
