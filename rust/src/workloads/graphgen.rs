//! Graph generators in CSR form.
//!
//! The paper's evaluation dataset: "two graphs, each synthetically
//! generated as a tree with depths D=7 and 9, and branch factor B=4 for
//! each node. In total, the graphs are of size (B^D - 1)/(B - 1) = 5,461
//! and 87,381."

use crate::util::rng::Rng;

/// A graph in CSR form: `adj_off[n]..adj_off[n+1]` indexes `adj_edges`.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    pub adj_off: Vec<i64>,
    pub adj_edges: Vec<i64>,
}

impl CsrGraph {
    pub fn nodes(&self) -> usize {
        self.adj_off.len() - 1
    }

    pub fn edges(&self) -> usize {
        self.adj_edges.len()
    }

    pub fn degree(&self, n: usize) -> usize {
        (self.adj_off[n + 1] - self.adj_off[n]) as usize
    }
}

/// Complete B-ary tree of the given depth (depth 1 = a single root).
/// Node ids are level-order, so node `n`'s children are `n*B+1 ..= n*B+B`
/// when in range — but we materialize explicit CSR as the paper's flow
/// (and ours) consumes adjacency from memory.
pub fn tree(branch: u64, depth: u32) -> CsrGraph {
    assert!(branch >= 1 && depth >= 1);
    let n_nodes: u64 = if branch == 1 {
        depth as u64
    } else {
        (branch.pow(depth) - 1) / (branch - 1)
    };
    // Internal nodes: all but the last level.
    let n_internal: u64 = if branch == 1 {
        (depth as u64).saturating_sub(1)
    } else if depth >= 1 {
        (branch.pow(depth - 1) - 1) / (branch - 1)
    } else {
        0
    };
    let mut adj_off = Vec::with_capacity(n_nodes as usize + 1);
    let mut adj_edges = Vec::with_capacity((n_nodes - 1) as usize);
    adj_off.push(0i64);
    for node in 0..n_nodes {
        if node < n_internal {
            for c in 0..branch {
                adj_edges.push((node * branch + 1 + c) as i64);
            }
        }
        adj_off.push(adj_edges.len() as i64);
    }
    CsrGraph { adj_off, adj_edges }
}

/// The paper's two datasets.
pub fn paper_tree_small() -> CsrGraph {
    tree(4, 7)
}

pub fn paper_tree_large() -> CsrGraph {
    tree(4, 9)
}

/// Random DAG (edges only from lower to higher ids — keeps parallel BFS
/// revisit-free like a tree, while stressing irregular degrees).
pub fn random_dag(nodes: usize, avg_degree: f64, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut adj_off = Vec::with_capacity(nodes + 1);
    let mut adj_edges = Vec::new();
    adj_off.push(0i64);
    for n in 0..nodes {
        let remaining = nodes - n - 1;
        if remaining > 0 {
            // Poisson-ish via repeated Bernoulli on a capped degree.
            let max_deg = remaining.min((avg_degree * 3.0) as usize + 1);
            for _ in 0..max_deg {
                if rng.chance(avg_degree / max_deg as f64) {
                    let target = n + 1 + rng.below(remaining as u64) as usize;
                    adj_edges.push(target as i64);
                }
            }
        }
        adj_off.push(adj_edges.len() as i64);
    }
    CsrGraph { adj_off, adj_edges }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tree_sizes_match_formula() {
        // (4^7 - 1)/3 = 5461 and (4^9 - 1)/3 = 87381 — the paper's sizes.
        assert_eq!(paper_tree_small().nodes(), 5_461);
        assert_eq!(paper_tree_large().nodes(), 87_381);
        assert_eq!(paper_tree_small().edges(), 5_460);
        assert_eq!(paper_tree_large().edges(), 87_380);
    }

    #[test]
    fn tree_structure_is_consistent() {
        let g = tree(3, 4); // 1 + 3 + 9 + 27 = 40 nodes
        assert_eq!(g.nodes(), 40);
        assert_eq!(g.degree(0), 3);
        // Leaves have no children.
        for n in 13..40 {
            assert_eq!(g.degree(n), 0, "node {n}");
        }
        // Every non-root node appears exactly once as a child.
        let mut seen = vec![0u32; g.nodes()];
        for &e in &g.adj_edges {
            seen[e as usize] += 1;
        }
        assert_eq!(seen[0], 0);
        assert!(seen[1..].iter().all(|&c| c == 1));
    }

    #[test]
    fn unary_tree_is_a_chain() {
        let g = tree(1, 5);
        assert_eq!(g.nodes(), 5);
        assert_eq!(g.edges(), 4);
        for n in 0..4 {
            assert_eq!(g.degree(n), 1);
        }
    }

    #[test]
    fn random_dag_is_forward_only() {
        let g = random_dag(200, 3.0, 42);
        assert_eq!(g.nodes(), 200);
        for n in 0..g.nodes() {
            for i in g.adj_off[n]..g.adj_off[n + 1] {
                let t = g.adj_edges[i as usize];
                assert!(t as usize > n, "edge {n}->{t} not forward");
                assert!((t as usize) < g.nodes());
            }
        }
    }

    #[test]
    fn random_dag_deterministic_by_seed() {
        let a = random_dag(100, 2.0, 7);
        let b = random_dag(100, 2.0, 7);
        assert_eq!(a.adj_edges, b.adj_edges);
        let c = random_dag(100, 2.0, 8);
        assert_ne!(a.adj_edges, c.adj_edges);
    }
}
