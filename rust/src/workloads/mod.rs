//! Benchmark workloads: Cilk-C sources, input generators and reference
//! results. These are the programs the paper's evaluation (and our
//! extended suite) compiles and runs.

pub mod bfs;
pub mod fib;
pub mod graphgen;
pub mod nqueens;
pub mod qsort;
pub mod relax;
pub mod rmw;
