//! N-Queens solution counting — a classic Cilk benchmark exercising void
//! spawns in loops with memory-accumulated results.
//!
//! Board state is passed positionally through task arguments (columns of
//! placed queens packed into three attack masks), so tasks stay closure-
//! sized — the same trick HardCilk kernels use.

/// Count solutions via parallel backtracking. `cols`/`diag1`/`diag2` are
/// attack bitmasks; a solution increments `solutions[0]`.
pub const NQUEENS_SRC: &str = "\
global int solutions[1];

void place(int n, int row, int cols, int diag1, int diag2) {
    if (row == n) {
        atomic_add(solutions, 0, 1);
        return;
    }
    for (int c = 0; c < n; c = c + 1) {
        int colbit = 1 << c;
        int d1bit = 1 << (row + c);
        int d2bit = 1 << (row - c + n - 1);
        bool free_ = (cols & colbit) == 0 && (diag1 & d1bit) == 0 && (diag2 & d2bit) == 0;
        if (free_) {
            cilk_spawn place(n, row + 1, cols | colbit, diag1 | d1bit, diag2 | d2bit);
        }
    }
    cilk_sync;
}
";

/// Known solution counts.
pub fn nqueens_ref(n: usize) -> u64 {
    [1, 1, 0, 0, 2, 10, 4, 40, 92, 352, 724][n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::oracle::run_oracle;
    use crate::interp::Memory;
    use crate::ir::expr::Value;
    use crate::lower::{compile, CompileOptions};

    #[test]
    fn oracle_counts_match_known_values() {
        let r = compile("nq", NQUEENS_SRC, &CompileOptions::no_dae()).unwrap();
        for n in [4usize, 5, 6] {
            let mem = Memory::new(&r.implicit);
            let (_, mem) = run_oracle(
                &r.implicit,
                mem,
                "place",
                &[Value::I64(n as i64), Value::I64(0), Value::I64(0), Value::I64(0), Value::I64(0)],
            )
            .unwrap();
            let sols = mem.dump_i64(r.implicit.global_by_name("solutions").unwrap());
            assert_eq!(sols[0] as u64, nqueens_ref(n), "n={n}");
        }
    }
}
