//! Parallel quicksort over a global array — stresses value-free spawns,
//! leaf partition code, and task trees whose shape depends on data.

pub const QSORT_SRC: &str = "\
global int data[];

int partition_(int lo, int hi) {
    int pivot = data[hi];
    int i = lo;
    for (int j = lo; j < hi; j = j + 1) {
        int dj = data[j];
        if (dj < pivot) {
            int di = data[i];
            data[i] = dj;
            data[j] = di;
            i = i + 1;
        }
    }
    int tmp = data[i];
    data[i] = data[hi];
    data[hi] = tmp;
    return i;
}

void qsort_(int lo, int hi) {
    if (lo >= hi) {
        return;
    }
    int p = partition_(lo, hi);
    cilk_spawn qsort_(lo, p - 1);
    cilk_spawn qsort_(p + 1, hi);
    cilk_sync;
}
";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::oracle::run_oracle;
    use crate::interp::Memory;
    use crate::ir::expr::Value;
    use crate::lower::{compile, CompileOptions};
    use crate::util::rng::Rng;

    #[test]
    fn sorts_random_arrays() {
        let r = compile("qs", QSORT_SRC, &CompileOptions::no_dae()).unwrap();
        let mut rng = Rng::new(3);
        for len in [1usize, 2, 17, 128] {
            let input: Vec<i64> = (0..len).map(|_| rng.range_i64(-100, 100)).collect();
            let mut mem = Memory::new(&r.implicit);
            mem.fill_i64(r.implicit.global_by_name("data").unwrap(), &input);
            let (_, mem) = run_oracle(
                &r.implicit,
                mem,
                "qsort_",
                &[Value::I64(0), Value::I64(len as i64 - 1)],
            )
            .unwrap();
            let mut expect = input.clone();
            expect.sort();
            assert_eq!(mem.dump_i64(r.implicit.global_by_name("data").unwrap()), expect);
        }
    }

    #[test]
    fn parallel_qsort_note() {
        // NOTE: parallel in-place quicksort on the WS runtime races on
        // `data` only across disjoint ranges — partition runs before the
        // spawns, so sibling tasks touch disjoint slices. The oracle test
        // above plus the ws equivalence test in rust/tests cover it.
    }
}
