//! Graph-relaxation workload — the numeric PE datapath that exercises the
//! three-layer stack (DESIGN.md §Hardware-Adaptation).
//!
//! Each visited node carries an F-dimensional feature vector in global
//! memory. The execute task applies `y = relu(x·W + b)`, writes `y` back,
//! and returns a frontier score `sum(y)` used to decide whether children
//! are expanded. The datapath is an `extern xla` task: Bombyx's scalar
//! reference lives here; the production path batches through the AOT
//! Pallas/XLA executable (`runtime::relax`), and the two are asserted
//! equal in tests.

use anyhow::{anyhow, Result};

use crate::interp::Memory;
use crate::ir::cfg::Module;
use crate::ir::expr::Value;
use crate::util::rng::Rng;

/// Feature width (fixed — matches the AOT-compiled kernel variants).
pub const F: usize = 16;

/// Cilk-C source: relax-and-expand traversal. The xla task `relax`
/// consumes a node id, transforms its feature row in `feat`, and returns
/// the frontier score scaled by 1000 (int); children expand while the
/// score stays positive.
pub const RELAX_SRC: &str = "\
global int adj_off[];
global int adj_edges[];
global int visited[];
global float feat[];
global int work_done[1];

extern xla int relax(int n);

void expand(int n) {
    visited[n] = 1;
    int score = cilk_spawn relax(n);
    cilk_sync;
    atomic_add(work_done, 0, 1);
    if (score > 0) {
        int off = adj_off[n];
        int end = adj_off[n + 1];
        for (int i = off; i < end; i = i + 1) {
            int child = adj_edges[i];
            if (visited[child] == 0) {
                cilk_spawn expand(child);
            }
        }
        cilk_sync;
    }
}
";

/// The relaxation weights: a fixed, well-conditioned deterministic matrix
/// (shared bit-for-bit with python/compile/kernels/ref.py — see
/// `weights()` docs there).
pub fn weights(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..F * F)
        .map(|_| (rng.unit_f32() - 0.5) * 0.25)
        .collect();
    let b: Vec<f32> = (0..F).map(|_| (rng.unit_f32() - 0.5) * 0.1).collect();
    (w, b)
}

/// Scalar reference datapath: y = relu(x W + b); returns (y, score).
pub fn relax_ref(x: &[f32], w: &[f32], b: &[f32]) -> (Vec<f32>, f32) {
    assert_eq!(x.len(), F);
    let mut y = vec![0f32; F];
    for j in 0..F {
        let mut acc = b[j];
        for i in 0..F {
            acc += x[i] * w[i * F + j];
        }
        y[j] = acc.max(0.0);
    }
    let score = y.iter().sum();
    (y, score)
}

/// Initialize memory: graph + random features (score-positive near the
/// root so traversals do real work).
pub fn init_memory(
    module: &Module,
    memory: &mut Memory,
    graph: &crate::workloads::graphgen::CsrGraph,
    seed: u64,
) -> Result<()> {
    crate::workloads::bfs::init_memory(module, memory, graph)?;
    let mut rng = Rng::new(seed ^ 0xFEA7);
    let feats: Vec<f32> = (0..graph.nodes() * F).map(|_| rng.unit_f32()).collect();
    let fid = module.global_by_name("feat").ok_or_else(|| anyhow!("no feat"))?;
    memory.fill_f32(fid, &feats);
    Ok(())
}

/// The scalar `XlaHandler`/sink body shared by oracle and WS reference
/// modes: load row n of `feat`, apply the datapath, write back, return
/// the score ×1000 as int.
pub fn scalar_relax(
    args: &[Value],
    feat: &mut [f32],
    w: &[f32],
    b: &[f32],
) -> Result<Value> {
    let n = args
        .first()
        .ok_or_else(|| anyhow!("relax expects node id"))?
        .as_i64() as usize;
    let row = n * F..(n + 1) * F;
    if row.end > feat.len() {
        return Err(anyhow!("relax: node {n} out of feature range"));
    }
    let x: Vec<f32> = feat[row.clone()].to_vec();
    let (y, score) = relax_ref(&x, w, b);
    feat[row].copy_from_slice(&y);
    Ok(Value::I64((score * 1000.0) as i64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relax_ref_is_deterministic_and_nonneg() {
        let (w, b) = weights(1);
        let x: Vec<f32> = (0..F).map(|i| i as f32 / F as f32).collect();
        let (y1, s1) = relax_ref(&x, &w, &b);
        let (y2, s2) = relax_ref(&x, &w, &b);
        assert_eq!(y1, y2);
        assert_eq!(s1, s2);
        assert!(y1.iter().all(|&v| v >= 0.0), "relu output");
        assert!((s1 - y1.iter().sum::<f32>()).abs() < 1e-6);
    }

    #[test]
    fn weights_are_seed_stable() {
        let (w1, _) = weights(7);
        let (w2, _) = weights(7);
        assert_eq!(w1, w2);
        let (w3, _) = weights(8);
        assert_ne!(w1, w3);
    }

    #[test]
    fn scalar_relax_updates_row_in_place() {
        let (w, b) = weights(1);
        let mut feat = vec![0.5f32; 3 * F];
        let before = feat.clone();
        let v = scalar_relax(&[Value::I64(1)], &mut feat, &w, &b).unwrap();
        // Row 1 changed; rows 0 and 2 untouched.
        assert_eq!(&feat[..F], &before[..F]);
        assert_eq!(&feat[2 * F..], &before[2 * F..]);
        assert_ne!(&feat[F..2 * F], &before[F..2 * F]);
        assert!(matches!(v, Value::I64(_)));
    }

    #[test]
    fn oob_node_errors() {
        let (w, b) = weights(1);
        let mut feat = vec![0.5f32; F];
        assert!(scalar_relax(&[Value::I64(5)], &mut feat, &w, &b).is_err());
    }
}
