//! Read-modify-write workload: a divide-and-conquer sweep whose leaves
//! do `data[i] = data[i] + i` (a load → bin → store triple), fold the
//! leaf sum into an `atomic_add` through a computed value (bin →
//! atomic_add), and return sums through spawn continuations (bin →
//! send_argument). Exists to exercise the widened superinstruction
//! peepholes — [`LoadBinStore`], [`BinAtomicAdd`], [`SendBin`] — under
//! the fused-vs-unfused and JIT-vs-interpreter differential suites.
//!
//! [`LoadBinStore`]: crate::exec::KOp::LoadBinStore
//! [`BinAtomicAdd`]: crate::exec::KOp::BinAtomicAdd
//! [`SendBin`]: crate::exec::KOp::SendBin

use anyhow::{anyhow, Result};

use crate::interp::Memory;
use crate::ir::cfg::Module;

/// Cilk-C source: recursive halving over `data[lo..hi)`; leaves bump
/// each element by its index, accumulate the leaf sum into `acc[0]`
/// (doubled, so the atomic's value is a computed temporary), and return
/// partial sums up the spawn tree.
pub const RMW_SRC: &str = "\
global int data[];
global int acc[4];

int bump(int lo, int hi) {
    if (hi - lo < 6) {
        int s = 0;
        for (int i = lo; i < hi; i = i + 1) {
            data[i] = data[i] + i;
            s = s + data[i];
        }
        atomic_add(acc, 0, s * 2);
        return s + lo;
    }
    int mid = lo + (hi - lo) / 2;
    int a = cilk_spawn bump(lo, mid);
    int b = cilk_spawn bump(mid, hi);
    cilk_sync;
    return a + b;
}
";

/// Problem size the reference and tests agree on.
pub const N: usize = 32;

/// Deterministic input image for `data`.
pub fn input() -> Vec<i64> {
    (0..N as i64).map(|i| (i * 7 + 3) % 17).collect()
}

/// Seed `data` for a run of `bump(0, N)`.
pub fn init_memory(module: &Module, mem: &mut Memory) -> Result<()> {
    let data = module
        .global_by_name("data")
        .ok_or_else(|| anyhow!("rmw module has no `data` global"))?;
    mem.fill_i64(data, &input());
    Ok(())
}

/// Reference semantics of `bump(lo, hi)` over `data`, returning
/// `(return value, acc[0] delta)`.
pub fn rmw_ref(data: &mut [i64], lo: i64, hi: i64) -> (i64, i64) {
    if hi - lo < 6 {
        let mut s = 0i64;
        for i in lo..hi {
            data[i as usize] += i;
            s += data[i as usize];
        }
        return (s + lo, s * 2);
    }
    let mid = lo + (hi - lo) / 2;
    let (ra, aa) = rmw_ref(data, lo, mid);
    let (rb, ab) = rmw_ref(data, mid, hi);
    (ra + rb, aa + ab)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_is_deterministic_and_touches_every_element() {
        let mut a = input();
        let mut b = input();
        let ra = rmw_ref(&mut a, 0, N as i64);
        let rb = rmw_ref(&mut b, 0, N as i64);
        assert_eq!(ra, rb);
        assert_eq!(a, b);
        for (i, (&before, &after)) in input().iter().zip(&a).enumerate() {
            assert_eq!(after, before + i as i64);
        }
        // acc delta is twice the post-update total.
        assert_eq!(ra.1, 2 * a.iter().sum::<i64>());
    }
}
