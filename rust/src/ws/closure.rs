//! Concurrent closure registry for the work-stealing runtime.
//!
//! A closure's lifecycle: created with join counter 1 (the creator's hold),
//! incremented once per child spawn targeting it, decremented by each
//! `send_argument` / counter notification and by `close_spawns`. The thread
//! that takes the counter to zero *fires* the closure (turns it into a
//! runnable task).
//!
//! Slots are `AtomicU64` bit patterns; each hole is written by exactly one
//! child (the task graph guarantees it), and the release-ordering on the
//! final decrement makes those writes visible to the firing thread.
//!
//! The registry is a set of per-worker *arenas*: each worker inserts into
//! its own shard (shard hint = worker id), and every shard keeps a free
//! list so fired slots are recycled instead of growing the table without
//! bound. Global live/peak counters feed the runtime's closure-footprint
//! stats without scanning.

use std::fmt;
use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::ArgList;
use crate::frontend::ast::Type;
use crate::ir::cfg::FuncId;
use crate::ir::expr::Value;

use super::plock;

/// A closure handle that no longer resolves: its closure fired (and the
/// slot was possibly recycled) or the owning job's arena was swept. On
/// the task path this is a contained, structured job failure
/// ([`super::Trap::StaleClosure`] via [`Registry::lookup`]); `get` /
/// `remove` keep the loud fail-stop panic for the fire path, where a
/// stale handle means free-list corruption.
#[derive(Clone, Copy, Debug)]
pub struct StaleHandle(pub i64);

impl fmt::Display for StaleHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The "stale closure handle" needle is pinned by
        // `JobError::classify` — never reword it.
        write!(
            f,
            "stale closure handle {} resolved after firing (slot recycled or swept)",
            self.0
        )
    }
}

impl std::error::Error for StaleHandle {}

/// Continuation reference carried by every task instance.
#[derive(Clone, Debug)]
pub enum Cont {
    /// Deliver to the external caller.
    Root,
    /// Fill `slot`, then decrement.
    Slot { clos: Arc<SharedClosure>, slot: u32 },
    /// Decrement only (void child).
    Counter { clos: Arc<SharedClosure> },
}

#[derive(Debug)]
pub struct SharedClosure {
    pub task: FuncId,
    pub slots: Vec<AtomicU64>,
    /// Shared with the task's compiled kernel — no per-closure type
    /// vector allocation.
    pub slot_tys: Arc<[Type]>,
    /// The continuation of the task that created this closure (where the
    /// continuation task will eventually send *its* result).
    pub cont: Mutex<Option<Cont>>,
    pub counter: AtomicU32,
    /// Registry handle (set right after insertion; -1 until then). Used to
    /// drop the registry reference when the closure fires.
    pub handle: AtomicI64,
}

impl SharedClosure {
    pub fn new(task: FuncId, slot_tys: Arc<[Type]>, cont: Cont) -> SharedClosure {
        SharedClosure {
            task,
            slots: slot_tys
                .iter()
                .map(|&t| AtomicU64::new(Value::zero_of(t).to_bits()))
                .collect(),
            slot_tys,
            cont: Mutex::new(Some(cont)),
            counter: AtomicU32::new(1),
            handle: AtomicI64::new(-1),
        }
    }

    /// Add one expected child (called by the spawner *before* the child can
    /// possibly run — the increment happens-before the push to any deque).
    #[inline]
    pub fn hold(&self) {
        self.counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Fill a hole slot. Each hole has exactly one writer.
    #[inline]
    pub fn fill(&self, slot: u32, value: Value) {
        let ty = self.slot_tys[slot as usize];
        self.slots[slot as usize].store(value.coerce(ty).to_bits(), Ordering::Relaxed);
    }

    /// Decrement the join counter; returns `true` if this call took it to
    /// zero (the caller must then fire the closure). Release/Acquire pairs
    /// make all slot writes visible to the firing thread.
    #[inline]
    pub fn release(&self) -> bool {
        let prev = self.counter.fetch_sub(1, Ordering::Release);
        debug_assert!(prev > 0, "join counter underflow");
        if prev == 1 {
            std::sync::atomic::fence(Ordering::Acquire);
            true
        } else {
            false
        }
    }

    /// Snapshot the argument values (call only after `release()` returned
    /// true). Inline for small arities — no allocation on the fire path.
    pub fn take_args(&self) -> ArgList {
        ArgList::from_fn(self.slots.len(), |i| {
            Value::from_bits(self.slot_tys[i], self.slots[i].load(Ordering::Relaxed))
        })
    }

    pub fn take_cont(&self) -> Cont {
        plock(&self.cont)
            .take()
            .expect("closure fired twice (join-counter bug)")
    }
}

struct Shard {
    /// (generation, closure). The generation bumps on every reuse of an
    /// entry, and is packed into the handle — so a stale handle from a
    /// fired closure still fails loudly instead of silently resolving to
    /// whatever closure recycled the slot.
    entries: Vec<(u32, Option<Arc<SharedClosure>>)>,
    /// Recycled entry indices (the per-arena free list).
    free: Vec<usize>,
}

/// Per-task-local closure handle table: `MakeClosure` handles are local
/// integer values; the registry resolves them when they cross task
/// boundaries as parameters (a closure handle is an ordinary i64 in the
/// IR).
///
/// Handles are `(generation << 32) | (index << shard_bits) | shard` into
/// per-worker sharded arenas; entries are dropped when fired (the `Arc`
/// keeps in-flight references alive) and their indices recycled through
/// the shard's free list, with the generation guarding against stale
/// handles hitting a recycled slot.
pub struct Registry {
    shards: Vec<Mutex<Shard>>,
    shard_bits: u32,
    live: AtomicUsize,
    peak: AtomicUsize,
}

/// Bits of a handle below the generation tag.
const GEN_SHIFT: u32 = 32;

impl Registry {
    pub fn new(shards: usize) -> Registry {
        let shards = shards.next_power_of_two();
        Registry {
            shards: (0..shards)
                .map(|_| Mutex::new(Shard { entries: Vec::new(), free: Vec::new() }))
                .collect(),
            shard_bits: shards.trailing_zeros(),
            live: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn decode(&self, handle: i64) -> (usize, usize, u32) {
        let low = (handle as u64 & 0xFFFF_FFFF) as usize;
        let shard = low & (self.shards.len() - 1);
        let idx = low >> self.shard_bits;
        let gen = (handle as u64 >> GEN_SHIFT) as u32;
        (shard, idx, gen)
    }

    /// Register a closure; returns its global handle. `shard_hint` is the
    /// inserting worker's id, so each worker allocates from its own arena.
    pub fn insert(&self, clos: Arc<SharedClosure>, shard_hint: usize) -> i64 {
        let shard = shard_hint & (self.shards.len() - 1);
        let (idx, gen) = {
            let mut s = plock(&self.shards[shard]);
            match s.free.pop() {
                Some(idx) => {
                    // Reuse bumps the generation so stale handles to the
                    // fired previous occupant stay detectable.
                    let gen = s.entries[idx].0.wrapping_add(1) & 0x7FFF_FFFF;
                    s.entries[idx] = (gen, Some(clos));
                    (idx, gen)
                }
                None => {
                    s.entries.push((0, Some(clos)));
                    (s.entries.len() - 1, 0)
                }
            }
        };
        // The handle packs the index into 32 - shard_bits bits; blowing
        // that budget must fail loudly, not bleed into the generation.
        assert!(
            idx < 1usize << (GEN_SHIFT - self.shard_bits),
            "closure arena shard overflow ({idx} live entries)"
        );
        let live = self.live.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(live, Ordering::Relaxed);
        ((gen as i64) << GEN_SHIFT) | ((idx as i64) << self.shard_bits) | shard as i64
    }

    /// Resolve a handle to its closure. Panics on a stale handle (the
    /// slot was fired — and possibly recycled — since): that is a
    /// join-counter or lowering bug, and must fail loudly.
    pub fn get(&self, handle: i64) -> Arc<SharedClosure> {
        let (shard, idx, gen) = self.decode(handle);
        let s = plock(&self.shards[shard]);
        let (cur_gen, entry) = &s.entries[idx];
        assert_eq!(*cur_gen, gen, "closure handle resolved after firing (slot recycled)");
        entry
            .as_ref()
            .expect("closure handle resolved after firing")
            .clone()
    }

    /// Non-panicking handle resolution for the task path: a stale handle
    /// (fired, swept, or out of range) becomes a [`StaleHandle`] error so
    /// the executor fails the *job* with `Trap::StaleClosure` instead of
    /// killing the process. Debug builds still assert — a stale handle
    /// on the task path is a join-counter or lowering bug worth a loud
    /// stop at a developer's desk, but not worth the whole resident pool
    /// in production.
    pub fn lookup(&self, handle: i64) -> Result<Arc<SharedClosure>, StaleHandle> {
        let (shard, idx, gen) = self.decode(handle);
        let s = plock(&self.shards[shard]);
        let resolved = s
            .entries
            .get(idx)
            .filter(|(cur_gen, _)| *cur_gen == gen)
            .and_then(|(_, entry)| entry.as_ref())
            .cloned();
        debug_assert!(
            resolved.is_some(),
            "closure handle {handle} resolved after firing (slot recycled or swept)"
        );
        resolved.ok_or(StaleHandle(handle))
    }

    /// Drop the registry's reference once fired; the entry index returns
    /// to the arena's free list. A stale handle (double fire) must panic
    /// even in release — silently evicting the slot's new occupant and
    /// double-pushing the free index would corrupt unrelated joins.
    pub fn remove(&self, handle: i64) {
        let (shard, idx, gen) = self.decode(handle);
        {
            let mut s = plock(&self.shards[shard]);
            assert_eq!(
                s.entries[idx].0, gen,
                "closure removed with a stale handle (fired twice?)"
            );
            s.entries[idx].1 = None;
            s.free.push(idx);
        }
        self.live.fetch_sub(1, Ordering::Relaxed);
    }

    /// Drop every live closure — whole-arena reclamation when the owning
    /// job completes or is cancelled. Entry generations survive, so a
    /// stale handle that somehow outlives the sweep still fails loudly
    /// on resolve instead of aliasing a recycled slot. Returns how many
    /// closures were dropped.
    pub fn clear(&self) -> usize {
        let mut dropped = 0usize;
        for shard in &self.shards {
            let mut guard = plock(shard);
            let Shard { entries, free } = &mut *guard;
            for (idx, (_gen, entry)) in entries.iter_mut().enumerate() {
                // Occupied entries are not on the free list yet; emptied
                // ones already are — push only what this sweep vacates.
                if entry.take().is_some() {
                    free.push(idx);
                    dropped += 1;
                }
            }
        }
        self.live.fetch_sub(dropped, Ordering::Relaxed);
        dropped
    }

    /// Number of live (unfired) closures — leak detector for tests.
    pub fn live(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// High-water mark of live closures over the registry's lifetime.
    pub fn live_peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tys(list: &[Type]) -> Arc<[Type]> {
        list.to_vec().into()
    }

    #[test]
    fn counter_protocol() {
        let c = SharedClosure::new(FuncId::new(0), tys(&[Type::Int, Type::Int]), Cont::Root);
        c.hold(); // child 1
        c.hold(); // child 2
        assert!(!c.release(), "child 1 completes");
        c.fill(0, Value::I64(7));
        assert!(!c.release(), "child 2 completes");
        c.fill(1, Value::I64(8));
        assert!(c.release(), "creator drops hold -> fires");
        assert_eq!(&c.take_args()[..], &[Value::I64(7), Value::I64(8)]);
    }

    #[test]
    fn concurrent_releases_fire_exactly_once() {
        for _ in 0..50 {
            let c = Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
            let n = 8;
            for _ in 0..n {
                c.hold();
            }
            let fired = std::sync::atomic::AtomicU32::new(0);
            std::thread::scope(|s| {
                for _ in 0..n {
                    let c = &c;
                    let fired = &fired;
                    s.spawn(move || {
                        if c.release() {
                            fired.fetch_add(1, Ordering::SeqCst);
                        }
                    });
                }
                if c.release() {
                    fired.fetch_add(1, Ordering::SeqCst);
                }
            });
            assert_eq!(fired.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn registry_roundtrip_and_remove() {
        let r = Registry::new(8);
        let c = Arc::new(SharedClosure::new(FuncId::new(3), tys(&[Type::Int]), Cont::Root));
        let h = r.insert(c.clone(), 5);
        assert_eq!(r.get(h).task, FuncId::new(3));
        assert_eq!(r.live(), 1);
        assert_eq!(r.live_peak(), 1);
        r.remove(h);
        assert_eq!(r.live(), 0);
        assert_eq!(r.live_peak(), 1, "peak sticks");
        // The Arc we hold keeps the closure alive regardless.
        assert_eq!(c.task, FuncId::new(3));
    }

    #[test]
    fn handles_distinct_across_shards() {
        let r = Registry::new(4);
        let mut handles = std::collections::HashSet::new();
        for i in 0..100 {
            let c = Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
            assert!(handles.insert(r.insert(c, i)));
        }
    }

    #[test]
    fn free_list_recycles_slots_with_fresh_generation() {
        let r = Registry::new(2);
        let mk = || Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
        let h1 = r.insert(mk(), 0);
        r.remove(h1);
        let h2 = r.insert(mk(), 0);
        // Same slot (low bits), new generation (high bits).
        assert_ne!(h1, h2, "recycled slot must carry a new generation");
        assert_eq!(h1 as u32, h2 as u32, "same arena slot is reused");
        let h3 = r.insert(mk(), 0);
        assert_ne!(h2, h3);
        assert_eq!(r.live(), 2);
        assert_eq!(r.live_peak(), 2);
    }

    #[test]
    fn clear_sweeps_live_closures_and_recycles_slots() {
        let r = Registry::new(4);
        let mk = || Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
        let handles: Vec<i64> = (0..10).map(|i| r.insert(mk(), i)).collect();
        r.remove(handles[3]); // one already fired: its slot is on the free list
        assert_eq!(r.live(), 9);
        assert_eq!(r.clear(), 9, "sweep drops exactly the unfired closures");
        assert_eq!(r.live(), 0);
        assert_eq!(r.clear(), 0, "second sweep is a no-op");
        // Slots recycle with fresh generations after the sweep.
        let h = r.insert(mk(), 0);
        assert_eq!(r.live(), 1);
        r.remove(h);
        assert_eq!(r.live(), 0);
    }

    #[test]
    fn lookup_reports_stale_handles_without_panicking() {
        // Release-mode contract (debug builds assert instead; these
        // stale probes therefore only run with debug_assertions off).
        let r = Registry::new(2);
        let mk = || Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
        let h1 = r.insert(mk(), 0);
        assert!(r.lookup(h1).is_ok(), "live handle resolves");
        if !cfg!(debug_assertions) {
            r.remove(h1);
            let err = r.lookup(h1).expect_err("fired handle is stale");
            assert!(
                err.to_string().contains("stale closure handle"),
                "classify needle must survive: {err}"
            );
            let _h2 = r.insert(mk(), 0); // recycles h1's slot
            assert!(r.lookup(h1).is_err(), "recycled slot stays stale");
            assert!(r.lookup(1 << 40).is_err(), "out-of-range index is stale, not a panic");
        }
    }

    #[test]
    #[should_panic(expected = "closure handle resolved after firing")]
    fn cleared_handle_fails_loudly() {
        let r = Registry::new(2);
        let c = Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
        let h = r.insert(c, 0);
        r.clear();
        let _ = r.get(h); // swept: must panic, not return a dangling entry
    }

    #[test]
    #[should_panic(expected = "closure handle resolved after firing")]
    fn stale_handle_into_recycled_slot_fails_loudly() {
        let r = Registry::new(2);
        let mk = || Arc::new(SharedClosure::new(FuncId::new(0), tys(&[]), Cont::Root));
        let h1 = r.insert(mk(), 0);
        r.remove(h1);
        let _h2 = r.insert(mk(), 0); // recycles h1's slot
        let _ = r.get(h1); // stale: must panic, not alias _h2's closure
    }
}
