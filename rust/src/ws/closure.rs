//! Concurrent closure registry for the work-stealing runtime.
//!
//! A closure's lifecycle: created with join counter 1 (the creator's hold),
//! incremented once per child spawn targeting it, decremented by each
//! `send_argument` / counter notification and by `close_spawns`. The thread
//! that takes the counter to zero *fires* the closure (turns it into a
//! runnable task).
//!
//! Slots are `AtomicU64` bit patterns; each hole is written by exactly one
//! child (the task graph guarantees it), and the release-ordering on the
//! final decrement makes those writes visible to the firing thread.

use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::frontend::ast::Type;
use crate::ir::cfg::FuncId;
use crate::ir::expr::Value;

/// Continuation reference carried by every task instance.
#[derive(Clone, Debug)]
pub enum Cont {
    /// Deliver to the external caller.
    Root,
    /// Fill `slot`, then decrement.
    Slot { clos: Arc<SharedClosure>, slot: u32 },
    /// Decrement only (void child).
    Counter { clos: Arc<SharedClosure> },
}

#[derive(Debug)]
pub struct SharedClosure {
    pub task: FuncId,
    pub slots: Vec<AtomicU64>,
    pub slot_tys: Vec<Type>,
    /// The continuation of the task that created this closure (where the
    /// continuation task will eventually send *its* result).
    pub cont: Mutex<Option<Cont>>,
    pub counter: AtomicU32,
    /// Registry handle (set right after insertion; -1 until then). Used to
    /// drop the registry reference when the closure fires.
    pub handle: AtomicI64,
}

impl SharedClosure {
    pub fn new(task: FuncId, slot_tys: Vec<Type>, cont: Cont) -> SharedClosure {
        SharedClosure {
            task,
            slots: slot_tys
                .iter()
                .map(|&t| AtomicU64::new(Value::zero_of(t).to_bits()))
                .collect(),
            slot_tys,
            cont: Mutex::new(Some(cont)),
            counter: AtomicU32::new(1),
            handle: AtomicI64::new(-1),
        }
    }

    /// Add one expected child (called by the spawner *before* the child can
    /// possibly run — the increment happens-before the push to any deque).
    #[inline]
    pub fn hold(&self) {
        self.counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Fill a hole slot. Each hole has exactly one writer.
    #[inline]
    pub fn fill(&self, slot: u32, value: Value) {
        let ty = self.slot_tys[slot as usize];
        self.slots[slot as usize].store(value.coerce(ty).to_bits(), Ordering::Relaxed);
    }

    /// Decrement the join counter; returns `true` if this call took it to
    /// zero (the caller must then fire the closure). Release/Acquire pairs
    /// make all slot writes visible to the firing thread.
    #[inline]
    pub fn release(&self) -> bool {
        let prev = self.counter.fetch_sub(1, Ordering::Release);
        debug_assert!(prev > 0, "join counter underflow");
        if prev == 1 {
            std::sync::atomic::fence(Ordering::Acquire);
            true
        } else {
            false
        }
    }

    /// Snapshot the argument values (call only after `release()` returned
    /// true).
    pub fn take_args(&self) -> Vec<Value> {
        self.slots
            .iter()
            .zip(&self.slot_tys)
            .map(|(s, &t)| Value::from_bits(t, s.load(Ordering::Relaxed)))
            .collect()
    }

    pub fn take_cont(&self) -> Cont {
        self.cont
            .lock()
            .unwrap()
            .take()
            .expect("closure fired twice (join-counter bug)")
    }
}

/// Per-task-local closure handle table: `MakeClosure` handles are local
/// integer values; the registry resolves them when they cross task
/// boundaries as parameters (a closure handle is an ordinary i64 in the
/// IR).
///
/// Handles are indices into a global append-only sharded table, so they
/// remain valid when passed between tasks/threads. Entries are dropped when
/// fired (the Arc keeps in-flight references alive).
pub struct Registry {
    shards: Vec<Mutex<Vec<Option<Arc<SharedClosure>>>>>,
    shard_bits: u32,
}

impl Registry {
    pub fn new(shards: usize) -> Registry {
        let shards = shards.next_power_of_two();
        Registry {
            shards: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
            shard_bits: shards.trailing_zeros(),
        }
    }

    /// Register a closure; returns its global handle.
    pub fn insert(&self, clos: Arc<SharedClosure>, shard_hint: usize) -> i64 {
        let shard = shard_hint & (self.shards.len() - 1);
        let mut v = self.shards[shard].lock().unwrap();
        let idx = v.len();
        v.push(Some(clos));
        ((idx as i64) << self.shard_bits) | shard as i64
    }

    /// Resolve a handle to its closure.
    pub fn get(&self, handle: i64) -> Arc<SharedClosure> {
        let shard = (handle as usize) & (self.shards.len() - 1);
        let idx = (handle >> self.shard_bits) as usize;
        self.shards[shard].lock().unwrap()[idx]
            .as_ref()
            .expect("closure handle resolved after firing")
            .clone()
    }

    /// Drop the registry's reference once fired (handle becomes invalid).
    pub fn remove(&self, handle: i64) {
        let shard = (handle as usize) & (self.shards.len() - 1);
        let idx = (handle >> self.shard_bits) as usize;
        self.shards[shard].lock().unwrap()[idx] = None;
    }

    /// Number of live (unfired) closures — leak detector for tests.
    pub fn live(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().iter().filter(|e| e.is_some()).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_protocol() {
        let c = SharedClosure::new(FuncId::new(0), vec![Type::Int, Type::Int], Cont::Root);
        c.hold(); // child 1
        c.hold(); // child 2
        assert!(!c.release(), "child 1 completes");
        c.fill(0, Value::I64(7));
        assert!(!c.release(), "child 2 completes");
        c.fill(1, Value::I64(8));
        assert!(c.release(), "creator drops hold -> fires");
        assert_eq!(c.take_args(), vec![Value::I64(7), Value::I64(8)]);
    }

    #[test]
    fn concurrent_releases_fire_exactly_once() {
        for _ in 0..50 {
            let c = Arc::new(SharedClosure::new(FuncId::new(0), vec![], Cont::Root));
            let n = 8;
            for _ in 0..n {
                c.hold();
            }
            let fired = std::sync::atomic::AtomicU32::new(0);
            std::thread::scope(|s| {
                for _ in 0..n {
                    let c = &c;
                    let fired = &fired;
                    s.spawn(move || {
                        if c.release() {
                            fired.fetch_add(1, Ordering::SeqCst);
                        }
                    });
                }
                if c.release() {
                    fired.fetch_add(1, Ordering::SeqCst);
                }
            });
            assert_eq!(fired.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn registry_roundtrip_and_remove() {
        let r = Registry::new(8);
        let c = Arc::new(SharedClosure::new(FuncId::new(3), vec![Type::Int], Cont::Root));
        let h = r.insert(c.clone(), 5);
        assert_eq!(r.get(h).task, FuncId::new(3));
        assert_eq!(r.live(), 1);
        r.remove(h);
        assert_eq!(r.live(), 0);
        // The Arc we hold keeps the closure alive regardless.
        assert_eq!(c.task, FuncId::new(3));
    }

    #[test]
    fn handles_distinct_across_shards() {
        let r = Registry::new(4);
        let mut handles = std::collections::HashSet::new();
        for i in 0..100 {
            let c = Arc::new(SharedClosure::new(FuncId::new(0), vec![], Cont::Root));
            assert!(handles.insert(r.insert(c, i)));
        }
    }
}
