//! Chase–Lev lock-free work-stealing deque.
//!
//! The owner pushes and pops at the *bottom* (LIFO hot end) with plain
//! loads/stores; thieves steal at the *top* (FIFO cold end) with a CAS.
//! No mutex anywhere on the task path — the only lock is the cold-path
//! retire list that keeps outgrown buffers alive until the deque drops
//! (a thief may still be reading a stale buffer pointer).
//!
//! Algorithm and memory orderings follow Chase & Lev, "Dynamic Circular
//! Work-Stealing Deques" (SPAA'05), in the C11 formulation of Lê,
//! Pop, Cohen & Zappa Nardelli, "Correct and Efficient Work-Stealing for
//! Weak Memory Models" (PPoPP'13).
//!
//! Values move by bitwise copy through `MaybeUninit`: a thief
//! speculatively copies the slot *before* its CAS and materializes the
//! value only if the CAS wins (a losing copy is dropped as raw bytes, so
//! non-`Copy` payloads are never double-dropped). The owner never
//! overwrites a slot a thief could still win: within one buffer
//! generation, index `b` wraps onto index `t` only when `b - t >= cap`,
//! and the owner grows into a fresh buffer before that.
//!
//! Known caveat (shared with crossbeam-deque, whose Buffer reads are the
//! same plain copies): a stalled thief's speculative copy can in
//! principle overlap an owner write to a wrapped slot whose element the
//! thief has already lost — the subsequent CAS is then guaranteed to
//! fail and the torn copy is discarded, but the overlapping plain
//! access is formally a data race under the abstract memory model
//! (Miri/TSan flag it). Making the copy UB-free requires per-word atomic
//! slot accesses as in the Lê et al. C11 formulation — a follow-up if
//! miri enters CI.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, Ordering};
use std::sync::Mutex;

use super::plock;

struct Buf<T> {
    mask: isize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

impl<T> Buf<T> {
    fn alloc(cap: usize) -> *mut Buf<T> {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[UnsafeCell<MaybeUninit<T>>]> =
            (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Box::into_raw(Box::new(Buf { mask: cap as isize - 1, slots }))
    }

    #[inline]
    unsafe fn read_raw(&self, i: isize) -> MaybeUninit<T> {
        self.slots[(i & self.mask) as usize].get().read()
    }

    #[inline]
    unsafe fn write_raw(&self, i: isize, v: MaybeUninit<T>) {
        self.slots[(i & self.mask) as usize].get().write(v);
    }
}

/// The deque. One owner thread calls [`Deque::push`] / [`Deque::pop`];
/// any thread may call [`Deque::steal`].
pub struct Deque<T> {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: AtomicPtr<Buf<T>>,
    /// Outgrown buffers, freed at drop (cold path: touched only when the
    /// owner doubles the buffer).
    retired: Mutex<Vec<*mut Buf<T>>>,
}

unsafe impl<T: Send> Send for Deque<T> {}
unsafe impl<T: Send> Sync for Deque<T> {}

const INITIAL_CAP: usize = 64;

impl<T> Default for Deque<T> {
    fn default() -> Deque<T> {
        Deque::new()
    }
}

impl<T> Deque<T> {
    pub fn new() -> Deque<T> {
        Deque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buf::alloc(INITIAL_CAP)),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Owner: push at the bottom.
    pub fn push(&self, value: T) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buf.load(Ordering::Relaxed);
        if b - t >= unsafe { (*buf).mask } + 1 {
            buf = self.grow(buf, t, b);
        }
        unsafe { (*buf).write_raw(b, MaybeUninit::new(value)) };
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner: pop at the bottom (LIFO).
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buf.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let m = unsafe { (*buf).read_raw(b) };
            if t == b {
                // Last element: race thieves for it.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                if won {
                    Some(unsafe { m.assume_init() })
                } else {
                    // A thief took it; drop `m` as raw bytes (no T drop).
                    None
                }
            } else {
                Some(unsafe { m.assume_init() })
            }
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief: steal at the top (FIFO). Returns `None` when empty or when
    /// the CAS lost a race — callers retry/back off either way.
    pub fn steal(&self) -> Option<T> {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let buf = self.buf.load(Ordering::Acquire);
            let m = unsafe { (*buf).read_raw(t) };
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                return Some(unsafe { m.assume_init() });
            }
            // Lost the race: `m` is dropped as raw bytes, no T drop.
        }
        None
    }

    /// Approximate occupancy (monitoring only).
    pub fn len_hint(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Number of outgrown buffers awaiting reclamation (monitoring and
    /// the executor's idle-reclaim path).
    pub fn retired_len(&self) -> usize {
        plock(&self.retired).len()
    }

    /// Free the retired buffers without waiting for drop.
    ///
    /// # Safety contract (checked by the caller, not the type system)
    ///
    /// Safe only when no thief can still hold a retired buffer pointer:
    /// the executor calls this at full quiescence — every deque empty
    /// and every worker's in-steal flag down. A thief that starts a
    /// [`Deque::steal`] afterwards loads the *current* buffer pointer,
    /// and only after observing `top < bottom`, so it can never touch a
    /// buffer retired before the quiescent point (modulo the formal
    /// stale-load caveat in the module docs, which this path shares).
    pub fn free_retired(&self) {
        for p in plock(&self.retired).drain(..) {
            unsafe { drop(Box::from_raw(p)) };
        }
    }

    /// Owner-only: double the buffer, copying live entries bitwise. The
    /// old buffer is retired, not freed — thieves may hold its pointer.
    fn grow(&self, old: *mut Buf<T>, t: isize, b: isize) -> *mut Buf<T> {
        let old_cap = unsafe { (*old).mask } + 1;
        let new = Buf::alloc((old_cap as usize) * 2);
        for i in t..b {
            unsafe { (*new).write_raw(i, (*old).read_raw(i)) };
        }
        self.buf.store(new, Ordering::Release);
        plock(&self.retired).push(old);
        new
    }
}

impl<T> Drop for Deque<T> {
    fn drop(&mut self) {
        // Sole owner now: drain remaining values, then free all buffers.
        let t = self.top.load(Ordering::Relaxed);
        let b = self.bottom.load(Ordering::Relaxed);
        let buf = self.buf.load(Ordering::Relaxed);
        unsafe {
            for i in t..b {
                drop((*buf).read_raw(i).assume_init());
            }
            drop(Box::from_raw(buf));
            for p in self.retired.get_mut().unwrap().drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn owner_lifo_thief_fifo() {
        let d: Deque<i64> = Deque::new();
        for i in 0..5 {
            d.push(i);
        }
        assert_eq!(d.steal(), Some(0), "thief takes the cold end");
        assert_eq!(d.pop(), Some(4), "owner takes the hot end");
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.steal(), Some(1));
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), None);
        assert_eq!(d.steal(), None);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let d: Deque<usize> = Deque::new();
        let n = INITIAL_CAP * 4 + 3;
        for i in 0..n {
            d.push(i);
        }
        assert_eq!(d.len_hint(), n);
        for i in (0..n).rev() {
            assert_eq!(d.pop(), Some(i));
        }
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn free_retired_reclaims_outgrown_buffers() {
        let d: Deque<Box<usize>> = Deque::new();
        for i in 0..INITIAL_CAP * 8 {
            d.push(Box::new(i));
        }
        assert!(d.retired_len() > 0, "growth must retire outgrown buffers");
        while d.pop().is_some() {}
        d.free_retired();
        assert_eq!(d.retired_len(), 0);
        // Still fully usable after reclamation (current buffer untouched).
        d.push(Box::new(7));
        assert_eq!(d.pop().as_deref(), Some(&7));
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn drop_frees_remaining_boxed_values() {
        // Box payloads: leaks/double-frees would crash under the test
        // allocator or miri; at minimum the values must be distinct.
        let d: Deque<Box<u64>> = Deque::new();
        for i in 0..100 {
            d.push(Box::new(i));
        }
        for _ in 0..40 {
            d.pop();
        }
        drop(d); // 60 boxes freed here
    }

    #[test]
    fn concurrent_steals_conserve_items() {
        const N: usize = 20_000;
        const THIEVES: usize = 4;
        let d: Deque<Box<usize>> = Deque::new();
        let taken_sum = AtomicUsize::new(0);
        let taken_count = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..THIEVES {
                s.spawn(|| loop {
                    if let Some(v) = d.steal() {
                        taken_sum.fetch_add(*v, Ordering::Relaxed);
                        taken_count.fetch_add(1, Ordering::Relaxed);
                    }
                    if taken_count.load(Ordering::Relaxed) >= N {
                        break;
                    }
                    std::hint::spin_loop();
                });
            }
            // Owner: interleave pushes and pops.
            let mut pushed = 0usize;
            while pushed < N {
                d.push(Box::new(pushed));
                pushed += 1;
                if pushed % 7 == 0 {
                    if let Some(v) = d.pop() {
                        taken_sum.fetch_add(*v, Ordering::Relaxed);
                        taken_count.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // Drain the rest so thieves terminate.
            while taken_count.load(Ordering::Relaxed) < N {
                if let Some(v) = d.pop() {
                    taken_sum.fetch_add(*v, Ordering::Relaxed);
                    taken_count.fetch_add(1, Ordering::Relaxed);
                } else {
                    std::hint::spin_loop();
                }
            }
        });
        assert_eq!(taken_count.load(Ordering::Relaxed), N);
        assert_eq!(taken_sum.load(Ordering::Relaxed), N * (N - 1) / 2);
    }
}
