//! Structured job-failure taxonomy for the resident executor.
//!
//! Before this module, every job failure was a stringly `anyhow::Error`:
//! callers could only substring-match, nothing could distinguish a
//! retryable hiccup from a deterministic trap, and the flood report
//! could not break terminal jobs down by cause. [`JobError`] carries a
//! [`JobErrorKind`] for programmatic handling (retry policy, shed
//! detection, chaos-determinism checks) next to the human-readable
//! message.
//!
//! `JobError` implements [`std::error::Error`], so the vendored `anyhow`
//! shim's blanket `From<E: std::error::Error>` lifts it through `?` in
//! every existing `anyhow::Result` caller — the structured kind lives in
//! the executor's error slot, and only flattens to a string when a
//! caller explicitly crosses into `anyhow`.

use std::fmt;
use std::time::Duration;

use super::executor::JobId;

/// Deterministic traps raised by the kernel machine itself: the same
/// program with the same inputs traps the same way every run.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trap {
    /// Out-of-bounds shared-memory load/store/atomic.
    Oob,
    /// Fuel exhausted: the per-frame kernel step limit or the job's
    /// [`super::JobSpec`] `fuel_budget`.
    Fuel,
    /// A closure handle resolved after its closure fired or was swept
    /// (a join-counter / lowering bug, contained to the job).
    StaleClosure,
}

/// What terminated a job — the programmatic half of a [`JobError`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum JobErrorKind {
    /// A deterministic kernel trap ([`Trap`]).
    Trap(Trap),
    /// A panic on a worker thread, caught and contained to this job.
    Panicked,
    /// The job's cooperative deadline fired at a dispatch boundary.
    DeadlineExceeded,
    /// The job exceeded its `max_live_closures` budget.
    ClosureBudget,
    /// A transient failure (chaos-injected, or a sink hiccup tagged
    /// transient) — the only kind retried by default.
    Transient,
    /// Cancelled through [`super::JobHandle::cancel`].
    Cancelled,
    /// Rejected at submission: the bounded admission queue was full.
    Shed,
    /// Everything else: kernel/sink errors, executor shutdown.
    Internal,
}

impl JobErrorKind {
    /// Stable short tag, used by the flood report's per-job outcome list
    /// and the chaos-determinism tests. Never reword these.
    pub fn tag(&self) -> &'static str {
        match self {
            JobErrorKind::Trap(Trap::Oob) => "trap:oob",
            JobErrorKind::Trap(Trap::Fuel) => "trap:fuel",
            JobErrorKind::Trap(Trap::StaleClosure) => "trap:stale-closure",
            JobErrorKind::Panicked => "panicked",
            JobErrorKind::DeadlineExceeded => "deadline",
            JobErrorKind::ClosureBudget => "closure-budget",
            JobErrorKind::Transient => "transient",
            JobErrorKind::Cancelled => "cancelled",
            JobErrorKind::Shed => "shed",
            JobErrorKind::Internal => "internal",
        }
    }

    /// Whether a retry policy may re-run the job after this error.
    /// Deterministic traps would fail identically; `Panicked` is
    /// additionally retryable when the policy opts in
    /// (`RetryPolicy::retry_on_panic`).
    pub fn retryable(&self) -> bool {
        matches!(self, JobErrorKind::Transient)
    }
}

impl fmt::Display for JobErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.tag())
    }
}

/// A structured job error: a [`JobErrorKind`] plus the message.
#[derive(Clone, Debug)]
pub struct JobError {
    kind: JobErrorKind,
    message: String,
}

impl JobError {
    pub fn new(kind: JobErrorKind, message: impl Into<String>) -> JobError {
        JobError { kind, message: message.into() }
    }

    pub fn kind(&self) -> JobErrorKind {
        self.kind
    }

    pub fn message(&self) -> &str {
        &self.message
    }

    pub fn panicked(id: JobId, payload: &str) -> JobError {
        JobError::new(JobErrorKind::Panicked, format!("{id} panicked: {payload}"))
    }

    pub fn deadline(id: JobId, deadline: Duration) -> JobError {
        JobError::new(
            JobErrorKind::DeadlineExceeded,
            format!("{id} exceeded its deadline of {:.1}ms", deadline.as_secs_f64() * 1e3),
        )
    }

    pub fn fuel_budget(id: JobId, budget: u64) -> JobError {
        JobError::new(
            JobErrorKind::Trap(Trap::Fuel),
            format!("{id} exhausted its fuel budget of {budget} dispatches"),
        )
    }

    pub fn closure_budget(id: JobId, budget: usize) -> JobError {
        JobError::new(
            JobErrorKind::ClosureBudget,
            format!("{id} exceeded its live-closure budget of {budget}"),
        )
    }

    pub fn transient(message: impl Into<String>) -> JobError {
        JobError::new(JobErrorKind::Transient, message)
    }

    pub fn cancelled(id: JobId) -> JobError {
        JobError::new(JobErrorKind::Cancelled, format!("{id} cancelled"))
    }

    pub fn shed(id: JobId, queued: usize, bound: usize) -> JobError {
        JobError::new(
            JobErrorKind::Shed,
            format!("{id} shed: admission queue full ({queued} queued, bound {bound})"),
        )
    }

    pub fn internal(message: impl Into<String>) -> JobError {
        JobError::new(JobErrorKind::Internal, message)
    }

    /// Classify an error that crossed an `anyhow` seam (kernel traps,
    /// sink errors) back into the taxonomy. The vendored `anyhow` shim
    /// flattens chains into the message eagerly, so substring matching
    /// on the canonical kernel/runtime messages is the classification —
    /// the needles below are pinned by unit tests against the literal
    /// messages in `exec/kernel.rs`, `ws/shared_mem.rs`, and
    /// `ws/closure.rs`.
    pub fn classify(err: &anyhow::Error) -> JobError {
        let message = err.to_string();
        let kind = if message.contains("out-of-bounds") {
            JobErrorKind::Trap(Trap::Oob)
        } else if message.contains("exceeded step limit") || message.contains("fuel budget") {
            JobErrorKind::Trap(Trap::Fuel)
        } else if message.contains("stale closure handle") {
            JobErrorKind::Trap(Trap::StaleClosure)
        } else if message.contains("injected transient fault") {
            JobErrorKind::Transient
        } else if message.contains("exceeded its deadline") {
            JobErrorKind::DeadlineExceeded
        } else if message.contains("live-closure budget") {
            JobErrorKind::ClosureBudget
        } else if message.contains("cancelled") {
            JobErrorKind::Cancelled
        } else {
            JobErrorKind::Internal
        };
        JobError { kind, message }
    }
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

// The blanket `From<E: std::error::Error>` on the vendored
// `anyhow::Error` makes `?` lift a JobError into every existing
// `anyhow::Result` caller (join sites in `ws::run`, the flood driver).
impl std::error::Error for JobError {}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn tags_are_stable() {
        // The chaos-determinism tests compare these strings across runs;
        // renaming one silently breaks recorded outcome sequences.
        let cases = [
            (JobErrorKind::Trap(Trap::Oob), "trap:oob"),
            (JobErrorKind::Trap(Trap::Fuel), "trap:fuel"),
            (JobErrorKind::Trap(Trap::StaleClosure), "trap:stale-closure"),
            (JobErrorKind::Panicked, "panicked"),
            (JobErrorKind::DeadlineExceeded, "deadline"),
            (JobErrorKind::ClosureBudget, "closure-budget"),
            (JobErrorKind::Transient, "transient"),
            (JobErrorKind::Cancelled, "cancelled"),
            (JobErrorKind::Shed, "shed"),
            (JobErrorKind::Internal, "internal"),
        ];
        for (kind, tag) in cases {
            assert_eq!(kind.tag(), tag);
        }
    }

    #[test]
    fn only_transient_is_retryable_by_default() {
        assert!(JobErrorKind::Transient.retryable());
        for kind in [
            JobErrorKind::Trap(Trap::Oob),
            JobErrorKind::Trap(Trap::Fuel),
            JobErrorKind::Trap(Trap::StaleClosure),
            JobErrorKind::Panicked,
            JobErrorKind::DeadlineExceeded,
            JobErrorKind::ClosureBudget,
            JobErrorKind::Cancelled,
            JobErrorKind::Shed,
            JobErrorKind::Internal,
        ] {
            assert!(!kind.retryable(), "{kind} must not be retryable");
        }
    }

    #[test]
    fn classify_maps_kernel_messages() {
        let cases = [
            ("out-of-bounds store: a[100] (len 2)", JobErrorKind::Trap(Trap::Oob)),
            ("`fib` exceeded step limit (infinite loop?)", JobErrorKind::Trap(Trap::Fuel)),
            (
                "stale closure handle 42 resolved after firing (slot recycled or swept)",
                JobErrorKind::Trap(Trap::StaleClosure),
            ),
            ("chaos: injected transient fault in job#3 at dispatch 7", JobErrorKind::Transient),
            ("job#5 exceeded its deadline of 30.0ms", JobErrorKind::DeadlineExceeded),
            ("job#6 exceeded its live-closure budget of 8", JobErrorKind::ClosureBudget),
            ("job#0 cancelled at dispatch boundary", JobErrorKind::Cancelled),
            ("xla sink returned 2 results for 3 instances", JobErrorKind::Internal),
        ];
        for (msg, kind) in cases {
            let classified = JobError::classify(&anyhow!("{msg}"));
            assert_eq!(classified.kind(), kind, "{msg}");
            assert_eq!(classified.to_string(), msg, "message must pass through untouched");
        }
    }

    #[test]
    fn display_substrings_are_pinned() {
        // Existing tests (executor_tests) assert on these substrings of
        // join() errors; the constructors must keep them.
        let c = JobError::cancelled(JobId(7));
        assert!(c.to_string().contains("cancelled"), "{c}");
        let s = JobError::shed(JobId(9), 4, 4);
        assert!(s.to_string().contains("shed"), "{s}");
        let d = JobError::deadline(JobId(1), Duration::from_millis(30));
        assert!(d.to_string().contains("deadline"), "{d}");
    }
}
