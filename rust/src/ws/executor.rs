//! Resident multi-job executor: the job-lifecycle layer over the WS
//! worker pool.
//!
//! The one-shot [`super::run`] model spins a pool up, drains a single
//! task graph, and tears everything down. This module keeps the pool
//! *resident*: clients [`Executor::submit`] jobs — a compiled kernel
//! program plus a root spawn — and get back a [`JobHandle`] to
//! `join()`/`cancel()`. The paper's explicit continuation-passing model
//! exists precisely so many independent task graphs can stream through a
//! fixed set of processing elements; this is that heavy-traffic scenario
//! for the software runtime.
//!
//! Lifecycle design:
//!
//! - **Per-job state.** Every task is tagged with an `Arc<JobState>`;
//!   completion detection moves from pool quiescence to a per-job
//!   outstanding-task counter (`pending`, seeded at 1 for the root).
//!   Closure arenas are partitioned per job ([`Registry`] per
//!   `JobState`), so cancelling a job reclaims *all* of its closures in
//!   one sweep and a leaky job can never exhaust another job's arena.
//! - **Fair admission.** At most `max_active_jobs` jobs run at once;
//!   excess submissions park in a FIFO until a slot frees. Active jobs
//!   feed roots (and spawn overflow past `max_inflight_per_job`) through
//!   per-job *injection lanes* drained round-robin, and workers poll the
//!   injector periodically even while their own deque is hot — so a
//!   resident `fib(30)` cannot starve a freshly submitted small job.
//! - **Cooperative cancellation.** [`JobHandle::cancel`] flips a flag
//!   checked at every dispatch boundary through the kernel loop's
//!   [`crate::exec::Machine::on_dispatch`] hook; queued tasks are
//!   discarded at pop, the job's injector lane and xla queue are purged,
//!   and the per-job registry sweep returns the live-closure count to
//!   zero.
//! - **Idle reclamation.** When the executor goes fully quiescent (no
//!   active or queued jobs, empty deques, no thief mid-steal) the
//!   retired Chase–Lev buffers outgrown by previous jobs are freed
//!   instead of accruing until drop.
//!
//! [`super::run`] / [`super::run_with_kernels`] are now thin wrappers:
//! construct an executor, submit one job, join it, tear down.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::exec::{ArgList, KernelProgram};
use crate::ir::cfg::FuncId;
use crate::ir::expr::Value;
use crate::obs::{self, trace::ArgVal};

use super::closure::{Cont, Registry};
use super::deque::Deque;
use super::shared_mem::SharedMemory;
use super::worker::{self, WsTask};
use super::{WsConfig, WsStats, XlaSink};

/// Executor-level configuration: the worker-pool knobs ([`WsConfig`])
/// plus the job-lifecycle knobs layered on top.
#[derive(Clone, Debug)]
pub struct ExecutorConfig {
    /// Worker pool shape (worker count, steal attempts).
    pub ws: WsConfig,
    /// Jobs allowed to run concurrently; excess submissions queue FIFO.
    pub max_active_jobs: usize,
    /// Spawn budget per job: once a job's outstanding-task count exceeds
    /// this, its new spawns overflow into its round-robin injector lane
    /// instead of the spawning worker's deque (fairness backpressure).
    pub max_inflight_per_job: usize,
    /// Shards in each job's closure arena (rounded up to a power of two).
    pub arena_shards: usize,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            ws: WsConfig::default(),
            max_active_jobs: 64,
            max_inflight_per_job: 4096,
            arena_shards: 64,
        }
    }
}

/// Hard sanity bounds: construction fails loudly instead of letting a
/// zero or absurd value panic deep inside worker spawn or arena setup.
const MAX_WORKERS: usize = 1024;
const MAX_ARENA_SHARDS: usize = 1 << 16;
const MAX_INFLIGHT: usize = 1 << 30;

impl ExecutorConfig {
    /// Validate before any thread or arena is created.
    pub fn validate(&self) -> Result<()> {
        if self.ws.workers == 0 {
            bail!("executor config: workers must be >= 1 (got 0)");
        }
        if self.ws.workers > MAX_WORKERS {
            bail!(
                "executor config: workers = {} exceeds the supported maximum of {MAX_WORKERS}",
                self.ws.workers
            );
        }
        if self.arena_shards == 0 {
            bail!("executor config: arena_shards must be >= 1 (got 0)");
        }
        if self.arena_shards > MAX_ARENA_SHARDS {
            bail!(
                "executor config: arena_shards = {} exceeds the supported maximum of {MAX_ARENA_SHARDS}",
                self.arena_shards
            );
        }
        if self.max_active_jobs == 0 {
            bail!("executor config: max_active_jobs must be >= 1 (got 0)");
        }
        if self.max_inflight_per_job == 0 {
            bail!("executor config: max_inflight_per_job must be >= 1 (got 0)");
        }
        if self.max_inflight_per_job > MAX_INFLIGHT {
            bail!(
                "executor config: max_inflight_per_job = {} exceeds the supported maximum of {MAX_INFLIGHT}",
                self.max_inflight_per_job
            );
        }
        Ok(())
    }
}

/// Identity of a submitted job (monotonic per executor).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job#{}", self.0)
    }
}

/// A unit of work for the executor: a compiled kernel program
/// (session-cached `Arc` — many jobs can share one program), a memory
/// image, and the root spawn.
pub struct Job {
    pub kernels: Arc<KernelProgram>,
    pub memory: SharedMemory,
    pub entry: String,
    pub args: Vec<Value>,
    pub xla_sink: Box<dyn XlaSink>,
}

impl Job {
    /// A job with no xla sink (programs without `extern xla`).
    pub fn new(
        kernels: Arc<KernelProgram>,
        memory: SharedMemory,
        entry: &str,
        args: &[Value],
    ) -> Job {
        Job {
            kernels,
            memory,
            entry: entry.to_string(),
            args: args.to_vec(),
            xla_sink: Box::new(super::NoXlaSink),
        }
    }
}

/// Per-job atomic counters, rolled into a [`WsStats`] snapshot at
/// completion (workers from every job update these concurrently).
#[derive(Default)]
pub(crate) struct JobCounters {
    pub(crate) tasks_run: AtomicU64,
    pub(crate) steals: AtomicU64,
    pub(crate) closures_made: AtomicU64,
    pub(crate) xla_batches: AtomicU64,
    pub(crate) xla_tasks: AtomicU64,
    pub(crate) instrs: AtomicU64,
}

/// Everything the workers need to run one job's tasks. Tasks carry an
/// `Arc<JobState>`, so a stolen task brings its whole job context with
/// it and stealing stays job-oblivious.
pub(crate) struct JobState {
    pub(crate) id: JobId,
    /// Root entry task name — the job's display name in traces/metrics.
    pub(crate) entry: String,
    pub(crate) kernels: Arc<KernelProgram>,
    pub(crate) memory: Arc<SharedMemory>,
    /// Per-job closure arena: cancellation sweeps it in one clear, and
    /// one job's closure footprint is invisible to every other job.
    pub(crate) registry: Registry,
    /// Tasks created but not yet finished; seeded at 1 for the root.
    /// Reaching zero completes the job (closures only count once fired).
    pub(crate) pending: AtomicU64,
    /// Cooperative-cancellation flag, checked at dispatch boundaries.
    pub(crate) cancelled: AtomicBool,
    /// Instances of this job's `extern xla` tasks awaiting batch flush.
    pub(crate) xla_queue: Mutex<Vec<(FuncId, Vec<Value>, Cont)>>,
    pub(crate) xla_sink: Box<dyn XlaSink>,
    pub(crate) counters: JobCounters,
    pub(crate) result: Mutex<Option<Value>>,
    pub(crate) error: Mutex<Option<anyhow::Error>>,
    /// One-shot claim on the terminal-state classification
    /// (completed/failed/cancelled): the *first* of `fail_job`,
    /// `JobHandle::cancel`, or `complete` to flip this counts the job,
    /// so lifetime aggregates add up even when a job fails or is
    /// cancelled long before its task graph drains (or never drains —
    /// the executor-drop path).
    classified: AtomicBool,
    /// One-shot claim on rolling the per-job counters into the executor
    /// totals (normally at `complete`, else at executor drop).
    counters_rolled: AtomicBool,
    /// Set by the worker that dispatches the job's first task (trace
    /// milestone).
    pub(crate) first_dispatched: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
    submitted_at: Instant,
    completed_at: Mutex<Option<Instant>>,
}

impl JobState {
    #[inline]
    pub(crate) fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Record the first error and abort the rest of the job (the
    /// cancelled flag doubles as the abort signal; workers discard the
    /// job's remaining tasks at dispatch boundaries).
    pub(crate) fn fail(&self, err: anyhow::Error) {
        let mut slot = self.error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(err);
        }
        drop(slot);
        self.cancelled.store(true, Ordering::SeqCst);
    }

    fn snapshot_stats(&self) -> WsStats {
        let c = &self.counters;
        WsStats {
            tasks_run: c.tasks_run.load(Ordering::Relaxed),
            steals: c.steals.load(Ordering::Relaxed),
            closures_made: c.closures_made.load(Ordering::Relaxed),
            max_live_closures: self.registry.live_peak() as u64,
            xla_batches: c.xla_batches.load(Ordering::Relaxed),
            xla_tasks: c.xla_tasks.load(Ordering::Relaxed),
            instrs: c.instrs.load(Ordering::Relaxed),
        }
    }
}

/// Lifetime aggregates across the executor's jobs. Job-level counters
/// (`tasks_run` …) roll in when a job reaches the end of its lifecycle,
/// so a snapshot taken mid-flight undercounts by the in-flight jobs.
#[derive(Clone, Debug, Default)]
pub struct ExecutorStats {
    pub jobs_submitted: u64,
    /// Jobs that delivered a root result with no error.
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub jobs_cancelled: u64,
    pub tasks_run: u64,
    pub steals: u64,
    pub closures_made: u64,
    pub xla_batches: u64,
    pub xla_tasks: u64,
    pub instrs: u64,
}

#[derive(Default)]
struct Totals {
    jobs_submitted: AtomicU64,
    jobs_completed: AtomicU64,
    jobs_failed: AtomicU64,
    jobs_cancelled: AtomicU64,
    tasks_run: AtomicU64,
    steals: AtomicU64,
    closures_made: AtomicU64,
    xla_batches: AtomicU64,
    xla_tasks: AtomicU64,
    instrs: AtomicU64,
}

/// Round-robin injection queues, one lane per job: a lane is created on
/// first push and dropped when drained, and `pop` rotates across lanes
/// so every active job's injected work makes progress regardless of how
/// much any single job floods in.
struct Injector {
    lanes: VecDeque<(JobId, VecDeque<WsTask>)>,
    total: usize,
}

impl Injector {
    fn new() -> Injector {
        Injector { lanes: VecDeque::new(), total: 0 }
    }

    fn push(&mut self, task: WsTask) {
        let id = task.job.id;
        match self.lanes.iter_mut().find(|(lid, _)| *lid == id) {
            Some((_, lane)) => lane.push_back(task),
            None => self.lanes.push_back((id, VecDeque::from([task]))),
        }
        self.total += 1;
    }

    /// Take one task, round-robin over lanes.
    fn pop(&mut self) -> Option<WsTask> {
        let (id, mut lane) = self.lanes.pop_front()?;
        let task = lane.pop_front();
        if !lane.is_empty() {
            self.lanes.push_back((id, lane));
        }
        debug_assert!(task.is_some(), "injector lanes are never left empty");
        if task.is_some() {
            self.total -= 1;
        }
        task
    }

    /// Remove every task of one job (cancellation).
    fn purge(&mut self, id: JobId) -> Vec<WsTask> {
        let mut out = Vec::new();
        let lanes = std::mem::take(&mut self.lanes);
        for (lid, mut lane) in lanes {
            if lid == id {
                out.extend(lane.drain(..));
            } else {
                self.lanes.push_back((lid, lane));
            }
        }
        self.total -= out.len();
        out
    }

    fn drain_all(&mut self) -> Vec<WsTask> {
        let mut out = Vec::new();
        for (_, mut lane) in std::mem::take(&mut self.lanes) {
            out.extend(lane.drain(..));
        }
        self.total = 0;
        out
    }
}

/// Admission control: the active set plus the FIFO of jobs waiting for a
/// slot (each queued entry parks its un-injected root task).
struct Admission {
    active: Vec<Arc<JobState>>,
    queued: VecDeque<(Arc<JobState>, WsTask)>,
}

/// State shared between the executor handle and its resident workers.
pub(crate) struct ExecShared {
    pub(crate) config: ExecutorConfig,
    /// Per-worker lock-free deques (owner hot end, thief cold end).
    pub(crate) deques: Vec<Deque<WsTask>>,
    injector: Mutex<Injector>,
    /// Mirror of the injector's total length, maintained under its lock:
    /// lets the worker loop skip the mutex when nothing is injected.
    injected: AtomicUsize,
    admission: Mutex<Admission>,
    pub(crate) shutdown: AtomicBool,
    /// Total queued xla instances across jobs (gates the flush scan).
    pub(crate) xla_pending: AtomicU64,
    /// Parked-worker wakeup.
    pub(crate) idle_lock: Mutex<()>,
    pub(crate) idle_cv: Condvar,
    /// Number of workers currently parked (gates notify syscalls).
    pub(crate) idle_workers: AtomicU64,
    /// Per-worker "inside a steal attempt" flags — a thief may hold a
    /// stale buffer pointer only while its flag is up, which is what
    /// makes quiescent retired-buffer reclamation safe.
    pub(crate) in_steal: Vec<AtomicBool>,
    totals: Totals,
}

impl ExecShared {
    #[inline]
    pub(crate) fn notify_if_idle(&self) {
        if self.idle_workers.load(Ordering::Relaxed) > 0 {
            self.idle_cv.notify_one();
        }
    }

    /// Enqueue into the task's per-job injector lane.
    pub(crate) fn inject(&self, task: WsTask) {
        {
            let mut inj = self.injector.lock().unwrap();
            inj.push(task);
            self.injected.store(inj.total, Ordering::SeqCst);
        }
        self.notify_if_idle();
    }

    /// Dequeue the next injected task, round-robin across job lanes.
    pub(crate) fn pop_injected(&self) -> Option<WsTask> {
        if self.injected.load(Ordering::SeqCst) == 0 {
            return None;
        }
        let mut inj = self.injector.lock().unwrap();
        let task = inj.pop();
        self.injected.store(inj.total, Ordering::SeqCst);
        task
    }

    /// Snapshot of the active set (xla flush iterates it).
    pub(crate) fn active_jobs(&self) -> Vec<Arc<JobState>> {
        self.admission.lock().unwrap().active.clone()
    }

    /// Free retired deque buffers if the executor is fully quiescent: no
    /// job active or queued, nothing injected, every deque empty, and no
    /// thief mid-steal. A thief entering `steal` *after* this check loads
    /// the current buffer pointer (never a retired one) and bails on
    /// `top >= bottom` before touching it, so only a thief already
    /// inside a steal — excluded by the `in_steal` flags — could hold a
    /// retired pointer. (Same formal-memory-model caveat as documented
    /// in [`super::deque`]: these are Relaxed/Acquire observations, not
    /// a proof against arbitrarily stale loads.)
    pub(crate) fn try_reclaim(&self) {
        let adm = self.admission.lock().unwrap();
        if !adm.active.is_empty() || !adm.queued.is_empty() {
            return;
        }
        if self.injected.load(Ordering::SeqCst) != 0 {
            return;
        }
        if self.deques.iter().any(|d| d.len_hint() != 0) {
            return;
        }
        if self.in_steal.iter().any(|f| f.load(Ordering::SeqCst)) {
            return;
        }
        for d in &self.deques {
            d.free_retired();
        }
        drop(adm);
    }

    fn stats(&self) -> ExecutorStats {
        let t = &self.totals;
        ExecutorStats {
            jobs_submitted: t.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: t.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: t.jobs_failed.load(Ordering::Relaxed),
            jobs_cancelled: t.jobs_cancelled.load(Ordering::Relaxed),
            tasks_run: t.tasks_run.load(Ordering::Relaxed),
            steals: t.steals.load(Ordering::Relaxed),
            closures_made: t.closures_made.load(Ordering::Relaxed),
            xla_batches: t.xla_batches.load(Ordering::Relaxed),
            xla_tasks: t.xla_tasks.load(Ordering::Relaxed),
            instrs: t.instrs.load(Ordering::Relaxed),
        }
    }
}

/// Decrement a job's outstanding-task count; the thread that takes it to
/// zero completes the job. Every task accounted in `pending` must funnel
/// through here exactly once — executed, discarded on cancellation,
/// purged from the injector, or drained from the xla queue.
pub(crate) fn finish_one(shared: &ExecShared, job: &Arc<JobState>) {
    if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        complete(shared, job);
    }
}

/// Terminal states a job is counted under, exactly once.
#[derive(Clone, Copy)]
enum Terminal {
    Completed,
    Failed,
    Cancelled,
}

/// Bump the executor total (and its metrics-registry mirror) for one
/// job's terminal state. Callers must hold the `classified` claim.
fn record_terminal(shared: &ExecShared, t: Terminal) {
    let (total, metric) = match t {
        Terminal::Completed => (&shared.totals.jobs_completed, "ws.jobs_completed"),
        Terminal::Failed => (&shared.totals.jobs_failed, "ws.jobs_failed"),
        Terminal::Cancelled => (&shared.totals.jobs_cancelled, "ws.jobs_cancelled"),
    };
    total.fetch_add(1, Ordering::Relaxed);
    obs::metrics::counter_add(metric, 1);
}

/// Record the job's first error, abort the rest of it, and count it as
/// failed *now* — not when (or if) its task graph finishes draining —
/// so lifetime aggregates include jobs the pool never completed.
pub(crate) fn fail_job(shared: &ExecShared, job: &JobState, err: anyhow::Error) {
    job.fail(err);
    if !job.classified.swap(true, Ordering::SeqCst) {
        record_terminal(shared, Terminal::Failed);
    }
}

/// Roll one job's counters into the executor lifetime totals.
fn roll_counters(shared: &ExecShared, s: &WsStats) {
    let t = &shared.totals;
    t.tasks_run.fetch_add(s.tasks_run, Ordering::Relaxed);
    t.steals.fetch_add(s.steals, Ordering::Relaxed);
    t.closures_made.fetch_add(s.closures_made, Ordering::Relaxed);
    t.xla_batches.fetch_add(s.xla_batches, Ordering::Relaxed);
    t.xla_tasks.fetch_add(s.xla_tasks, Ordering::Relaxed);
    t.instrs.fetch_add(s.instrs, Ordering::Relaxed);
}

/// End of a job's lifecycle: sweep its closure arena, roll its counters
/// into the executor totals, free its admission slot (admitting the next
/// queued job), wake joiners, and try idle reclamation.
fn complete(shared: &ExecShared, job: &Arc<JobState>) {
    // Reclaims every closure a cancelled job left unfired; a no-op for a
    // cleanly drained graph. Runs strictly after the job's last task
    // (pending just hit zero), so nothing can still resolve handles.
    job.registry.clear();

    if !job.counters_rolled.swap(true, Ordering::SeqCst) {
        roll_counters(shared, &job.snapshot_stats());
    }
    // Failed and cancelled jobs were classified when `fail_job` /
    // `JobHandle::cancel` ran; everything still unclaimed here finished
    // cleanly (or was cancelled after delivering its result, which
    // counts as completed).
    if !job.classified.swap(true, Ordering::SeqCst) {
        let failed = job.error.lock().unwrap().is_some();
        let delivered = job.result.lock().unwrap().is_some();
        let terminal = if failed {
            Terminal::Failed
        } else if !delivered && job.cancelled.load(Ordering::SeqCst) {
            Terminal::Cancelled
        } else {
            Terminal::Completed
        };
        record_terminal(shared, terminal);
    }
    let now = Instant::now();
    *job.completed_at.lock().unwrap() = Some(now);
    let latency = now.duration_since(job.submitted_at);
    obs::metrics::observe_ms("ws.job.latency_ms", latency);
    if obs::trace_enabled() {
        obs::trace::async_end(
            job.entry.clone(),
            "job",
            job.id.0,
            vec![("latency_ms", ArgVal::F64(latency.as_secs_f64() * 1e3))],
        );
    }

    // Free the admission slot; admit the longest-waiting queued job.
    let next_root = {
        let mut adm = shared.admission.lock().unwrap();
        adm.active.retain(|j| j.id != job.id);
        if adm.active.len() < shared.config.max_active_jobs {
            if let Some((next, root)) = adm.queued.pop_front() {
                adm.active.push(next);
                Some(root)
            } else {
                None
            }
        } else {
            None
        }
    };
    if let Some(root) = next_root {
        if obs::trace_enabled() {
            obs::trace::async_instant("admit", "job", root.job.id.0, Vec::new());
        }
        shared.inject(root);
    }

    {
        let mut done = job.done.lock().unwrap();
        *done = true;
    }
    job.done_cv.notify_all();
    shared.try_reclaim();
}

/// The resident executor: a fixed pool of worker threads draining tasks
/// from every submitted job. Dropping it shuts the pool down (in-flight
/// jobs are failed so joiners cannot hang).
pub struct Executor {
    shared: Arc<ExecShared>,
    threads: Vec<std::thread::JoinHandle<()>>,
    next_job: AtomicU64,
}

impl Executor {
    /// Validate the configuration and spawn the resident worker pool.
    pub fn new(config: ExecutorConfig) -> Result<Executor> {
        config.validate()?;
        let workers = config.ws.workers;
        let shared = Arc::new(ExecShared {
            config,
            deques: (0..workers).map(|_| Deque::new()).collect(),
            injector: Mutex::new(Injector::new()),
            injected: AtomicUsize::new(0),
            admission: Mutex::new(Admission { active: Vec::new(), queued: VecDeque::new() }),
            shutdown: AtomicBool::new(false),
            xla_pending: AtomicU64::new(0),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            idle_workers: AtomicU64::new(0),
            in_steal: (0..workers).map(|_| AtomicBool::new(false)).collect(),
            totals: Totals::default(),
        });
        let mut threads = Vec::with_capacity(workers);
        for wid in 0..workers {
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("bombyx-ws-{wid}"))
                .spawn(move || worker::worker_loop(wid, &sh));
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    shared.idle_cv.notify_all();
                    for t in threads {
                        let _ = t.join();
                    }
                    bail!("spawning ws worker {wid}: {e}");
                }
            }
        }
        Ok(Executor { shared, threads, next_job: AtomicU64::new(0) })
    }

    pub fn workers(&self) -> usize {
        self.shared.deques.len()
    }

    /// Submit a job. Fails fast (before consuming an admission slot) if
    /// the entry task does not exist in the job's kernel program.
    pub fn submit(&self, job: Job) -> Result<JobHandle> {
        let Job { kernels, memory, entry, args, xla_sink } = job;
        let fid = kernels
            .func_by_name(&entry)
            .ok_or_else(|| anyhow!("no task named `{entry}`"))?;
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let state = Arc::new(JobState {
            id,
            entry,
            kernels,
            memory: Arc::new(memory),
            registry: Registry::new(self.shared.config.arena_shards),
            pending: AtomicU64::new(1),
            cancelled: AtomicBool::new(false),
            xla_queue: Mutex::new(Vec::new()),
            xla_sink,
            counters: JobCounters::default(),
            result: Mutex::new(None),
            error: Mutex::new(None),
            classified: AtomicBool::new(false),
            counters_rolled: AtomicBool::new(false),
            first_dispatched: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
            submitted_at: Instant::now(),
            completed_at: Mutex::new(None),
        });
        let root = WsTask {
            job: Arc::clone(&state),
            task: fid,
            args: ArgList::from_slice(&args),
            cont: Cont::Root,
        };
        self.shared.totals.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        obs::metrics::counter_add("ws.jobs_submitted", 1);
        if obs::trace_enabled() {
            // Async span: the job lifecycle migrates across threads, so
            // submit→complete is a `b`/`e` pair keyed by the job id.
            obs::trace::async_begin(
                state.entry.clone(),
                "job",
                id.0,
                vec![("job", ArgVal::I64(id.0 as i64))],
            );
        }
        let mut admitted = Some(root);
        {
            let mut adm = self.shared.admission.lock().unwrap();
            if adm.active.len() < self.shared.config.max_active_jobs {
                adm.active.push(Arc::clone(&state));
            } else {
                adm.queued.push_back((Arc::clone(&state), admitted.take().unwrap()));
            }
        }
        let went_in = admitted.is_some();
        if let Some(root) = admitted {
            self.shared.inject(root);
        }
        if obs::trace_enabled() {
            let mark = if went_in { "admit" } else { "queue" };
            obs::trace::async_instant(mark, "job", id.0, Vec::new());
        }
        Ok(JobHandle { job: state, shared: Arc::clone(&self.shared) })
    }

    /// Lifetime aggregates (completed jobs; see [`ExecutorStats`]).
    pub fn stats(&self) -> ExecutorStats {
        self.shared.stats()
    }

    /// Retired (outgrown, not yet freed) deque buffers across workers —
    /// observability for the idle-reclamation path.
    pub fn retired_buffers(&self) -> usize {
        self.shared.deques.iter().map(|d| d.retired_len()).sum()
    }

    /// Publish the lifetime aggregates into the metrics registry under
    /// their canonical `ws.*` names (authoritative snapshot — overwrites
    /// the incrementally-maintained job counts with the same values).
    /// No-op while metrics are disabled.
    pub fn publish_metrics(&self) {
        if !obs::metrics_enabled() {
            return;
        }
        let s = self.stats();
        obs::metrics::counter_set("ws.jobs_submitted", s.jobs_submitted);
        obs::metrics::counter_set("ws.jobs_completed", s.jobs_completed);
        obs::metrics::counter_set("ws.jobs_failed", s.jobs_failed);
        obs::metrics::counter_set("ws.jobs_cancelled", s.jobs_cancelled);
        obs::metrics::counter_set("ws.tasks_run", s.tasks_run);
        obs::metrics::counter_set("ws.steals", s.steals);
        obs::metrics::counter_set("ws.closures_made", s.closures_made);
        obs::metrics::counter_set("ws.xla_batches", s.xla_batches);
        obs::metrics::counter_set("ws.xla_tasks", s.xla_tasks);
        obs::metrics::counter_set("ws.instrs_retired", s.instrs);
        obs::metrics::gauge_set("ws.workers", self.workers() as f64);
        obs::metrics::gauge_set("ws.retired_buffers", self.retired_buffers() as f64);
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.idle_cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // Workers are gone; fail whatever is still in flight so late
        // joiners see an error instead of hanging on the condvar.
        let orphans = {
            let mut inj = self.shared.injector.lock().unwrap();
            let tasks = inj.drain_all();
            self.shared.injected.store(0, Ordering::SeqCst);
            tasks
        };
        drop(orphans);
        let leftovers: Vec<Arc<JobState>> = {
            let mut adm = self.shared.admission.lock().unwrap();
            let mut jobs = std::mem::take(&mut adm.active);
            jobs.extend(adm.queued.drain(..).map(|(j, _)| j));
            jobs
        };
        for job in leftovers {
            // `fail_job` (not a bare `fail`) so drop-orphaned jobs land
            // in `jobs_failed`, and their counters roll in — lifetime
            // aggregates must add up even for jobs complete() never saw.
            fail_job(&self.shared, &job, anyhow!("executor shut down with {} in flight", job.id));
            if !job.counters_rolled.swap(true, Ordering::SeqCst) {
                roll_counters(&self.shared, &job.snapshot_stats());
            }
            job.registry.clear();
            if obs::trace_enabled() {
                obs::trace::async_end(
                    job.entry.clone(),
                    "job",
                    job.id.0,
                    vec![("dropped", ArgVal::I64(1))],
                );
            }
            {
                let mut done = job.done.lock().unwrap();
                *done = true;
            }
            job.done_cv.notify_all();
        }
    }
}

/// Client-side handle to a submitted job.
pub struct JobHandle {
    job: Arc<JobState>,
    shared: Arc<ExecShared>,
}

impl JobHandle {
    pub fn id(&self) -> JobId {
        self.job.id
    }

    pub fn is_finished(&self) -> bool {
        *self.job.done.lock().unwrap()
    }

    /// Block until the job reaches the end of its lifecycle (result,
    /// error, or cancellation drained).
    pub fn wait(&self) {
        let mut done = self.job.done.lock().unwrap();
        while !*done {
            done = self.job.done_cv.wait(done).unwrap();
        }
        drop(done);
        self.shared.try_reclaim();
    }

    /// Wait and consume the handle: root result, final memory image, and
    /// this job's stats. The memory is the `Arc` shared with any tasks
    /// that ran it — sole ownership returns once the executor (or at
    /// least this job's last task) is gone.
    pub fn join(self) -> Result<(Value, Arc<SharedMemory>, WsStats)> {
        self.wait();
        let stats = self.job.snapshot_stats();
        if let Some(err) = self.job.error.lock().unwrap().take() {
            return Err(err);
        }
        let result = self.job.result.lock().unwrap().take();
        match result {
            Some(value) => Ok((value, Arc::clone(&self.job.memory), stats)),
            None if self.job.is_cancelled() => Err(anyhow!("{} cancelled", self.job.id)),
            None => Err(anyhow!("task graph drained without a root result")),
        }
    }

    /// Cooperatively cancel the job. Queued-but-unstarted jobs complete
    /// immediately; in-flight jobs stop at the next dispatch boundary of
    /// each of their tasks, and the job's injector lane, xla queue, and
    /// closure arena are reclaimed. A job may still complete normally if
    /// its root result was already delivered.
    pub fn cancel(&self) {
        if self.job.cancelled.swap(true, Ordering::SeqCst) {
            return;
        }
        // Count the cancellation *now* (unless the root result was
        // already delivered — that job still completes normally), so
        // executor totals include jobs whose graphs take a while to
        // drain, or never do.
        let delivered = self.job.result.lock().unwrap().is_some();
        if !delivered && !self.job.classified.swap(true, Ordering::SeqCst) {
            record_terminal(&self.shared, Terminal::Cancelled);
        }
        if obs::trace_enabled() {
            obs::trace::async_instant("cancel", "job", self.job.id.0, Vec::new());
        }
        // Still parked in the admission queue? Its root never ran: drop
        // the parked task and retire the job's only pending count.
        let parked = {
            let mut adm = self.shared.admission.lock().unwrap();
            adm.queued
                .iter()
                .position(|(j, _)| j.id == self.job.id)
                .and_then(|pos| adm.queued.remove(pos))
        };
        if let Some((job, root)) = parked {
            drop(root);
            finish_one(&self.shared, &job);
            return;
        }
        // In flight: purge the injector lane and the xla queue — workers
        // discard everything else at dispatch boundaries.
        let purged = {
            let mut inj = self.shared.injector.lock().unwrap();
            let tasks = inj.purge(self.job.id);
            self.shared.injected.store(inj.total, Ordering::SeqCst);
            tasks
        };
        for task in purged {
            let job = Arc::clone(&task.job);
            drop(task);
            finish_one(&self.shared, &job);
        }
        let drained: Vec<_> = {
            let mut q = self.job.xla_queue.lock().unwrap();
            q.drain(..).collect()
        };
        if !drained.is_empty() {
            self.shared.xla_pending.fetch_sub(drained.len() as u64, Ordering::SeqCst);
            let n = drained.len();
            drop(drained);
            for _ in 0..n {
                finish_one(&self.shared, &self.job);
            }
        }
        self.shared.idle_cv.notify_all();
    }

    /// Live closures in this job's arena (0 after completion or a
    /// drained cancellation).
    pub fn live_closures(&self) -> usize {
        self.job.registry.live()
    }

    /// Stats snapshot (mid-flight snapshots are racy but monotonic).
    pub fn stats(&self) -> WsStats {
        self.job.snapshot_stats()
    }

    /// Submission-to-completion latency, once finished.
    pub fn latency(&self) -> Option<Duration> {
        self.job
            .completed_at
            .lock()
            .unwrap()
            .map(|t| t.duration_since(self.job.submitted_at))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_valid() {
        assert!(ExecutorConfig::default().validate().is_ok());
    }

    #[test]
    fn injector_empty_bookkeeping() {
        // Lane rotation under real tasks is covered by the fairness test
        // in rust/tests/executor_tests.rs; the empty-state invariants are
        // checkable without a job.
        let mut inj = Injector::new();
        assert!(inj.pop().is_none());
        assert_eq!(inj.total, 0);
        assert!(inj.drain_all().is_empty());
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        let cases: Vec<(ExecutorConfig, &str)> = vec![
            (
                ExecutorConfig {
                    ws: WsConfig { workers: 0, steal_tries: 4 },
                    ..ExecutorConfig::default()
                },
                "workers",
            ),
            (
                ExecutorConfig {
                    ws: WsConfig { workers: MAX_WORKERS + 1, steal_tries: 4 },
                    ..ExecutorConfig::default()
                },
                "workers",
            ),
            (ExecutorConfig { arena_shards: 0, ..ExecutorConfig::default() }, "arena_shards"),
            (
                ExecutorConfig { arena_shards: MAX_ARENA_SHARDS * 2, ..ExecutorConfig::default() },
                "arena_shards",
            ),
            (ExecutorConfig { max_active_jobs: 0, ..ExecutorConfig::default() }, "max_active_jobs"),
            (
                ExecutorConfig { max_inflight_per_job: 0, ..ExecutorConfig::default() },
                "max_inflight_per_job",
            ),
        ];
        for (cfg, needle) in cases {
            let err = cfg.validate().expect_err("must be rejected");
            assert!(err.to_string().contains(needle), "{err} should mention {needle}");
            // The same error must surface from construction, before any
            // thread is spawned.
            let err = Executor::new(cfg).expect_err("construction must fail");
            assert!(err.to_string().contains(needle), "{err}");
        }
    }
}
