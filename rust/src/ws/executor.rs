//! Resident multi-job executor: the job-lifecycle layer over the WS
//! worker pool.
//!
//! The one-shot [`super::run`] model spins a pool up, drains a single
//! task graph, and tears everything down. This module keeps the pool
//! *resident*: clients [`Executor::submit`] jobs — a compiled kernel
//! program plus a root spawn — and get back a [`JobHandle`] to
//! `join()`/`cancel()`. The paper's explicit continuation-passing model
//! exists precisely so many independent task graphs can stream through a
//! fixed set of processing elements; this is that heavy-traffic scenario
//! for the software runtime.
//!
//! Lifecycle design:
//!
//! - **Per-job state.** Every task is tagged with an `Arc<JobState>`;
//!   completion detection moves from pool quiescence to a per-job
//!   outstanding-task counter (`pending`, seeded at 1 for the root).
//!   Closure arenas are partitioned per job ([`Registry`] per
//!   `JobState`), so cancelling a job reclaims *all* of its closures in
//!   one sweep and a leaky job can never exhaust another job's arena.
//! - **Fair admission, bounded.** At most `max_active_jobs` jobs run at
//!   once; excess submissions park in a FIFO until a slot frees — and
//!   the FIFO itself is bounded by `max_queued_jobs`: past it, `submit`
//!   *sheds* the job (structured [`JobErrorKind::Shed`]) instead of
//!   growing without bound. Active jobs feed roots (and spawn overflow
//!   past `max_inflight_per_job`) through per-job *injection lanes*
//!   drained round-robin, and workers poll the injector periodically
//!   even while their own deque is hot — so a resident `fib(30)` cannot
//!   starve a freshly submitted small job.
//! - **Fault containment.** A panic inside a task is caught at the
//!   dispatch boundary (see [`super::worker`]) and becomes a first-
//!   error-wins [`fail_job`] for the owning job only; a worker thread
//!   that dies anyway (a panic outside the catch) is respawned by the
//!   supervisor thread, so the pool never silently shrinks. Per-job
//!   [`JobSpec`] deadlines/budgets are enforced cooperatively at the
//!   same `on_dispatch` seam cancellation uses, and retryable failures
//!   ([`JobErrorKind::retryable`], plus panics when the policy opts in)
//!   are re-run by the supervisor after a deterministic
//!   exponential-backoff delay ([`RetryPolicy::delay_for`]).
//! - **Cooperative cancellation.** [`JobHandle::cancel`] flips a flag
//!   checked at every dispatch boundary through the kernel loop's
//!   [`crate::exec::Machine::on_dispatch`] hook; queued tasks are
//!   discarded at pop, the job's injector lane and xla queue are purged,
//!   and the per-job registry sweep returns the live-closure count to
//!   zero.
//! - **Idle reclamation.** When the executor goes fully quiescent (no
//!   active or queued jobs, empty deques, no thief mid-steal) the
//!   retired Chase–Lev buffers outgrown by previous jobs are freed
//!   instead of accruing until drop.
//!
//! [`super::run`] / [`super::run_with_kernels`] are now thin wrappers:
//! construct an executor, submit one job, join it, tear down.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::exec::{ArgList, KernelProgram};
use crate::ir::cfg::FuncId;
use crate::ir::expr::Value;
use crate::obs::{self, trace::ArgVal};
use crate::util::rng::Rng;

use super::closure::{Cont, Registry};
use super::deque::Deque;
use super::error::{JobError, JobErrorKind};
use super::fault::{FaultPlan, InjectedFault, JobFaults};
use super::shared_mem::SharedMemory;
use super::worker::{self, WsTask};
use super::{plock, WsConfig, WsStats, XlaSink};

/// Retry policy applied per job ([`JobSpec::retry`]): how many attempts
/// a job gets, and how long to back off between them. Only kinds marked
/// [`JobErrorKind::retryable`] are retried — plus [`JobErrorKind::Panicked`]
/// when `retry_on_panic` opts in (chaos floods use this to converge).
#[derive(Clone, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the first; 1 = never retry.
    pub max_attempts: u32,
    /// Base backoff before attempt 2; doubles per further attempt.
    pub backoff: Duration,
    /// Treat a caught panic as retryable (off by default: panics are
    /// usually deterministic bugs that would recur).
    pub retry_on_panic: bool,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, backoff: Duration::from_millis(10), retry_on_panic: false }
    }
}

const MAX_RETRY_ATTEMPTS: u32 = 64;
const MAX_RETRY_BACKOFF: Duration = Duration::from_secs(60);

impl RetryPolicy {
    /// The delay before `attempt` (2-based: the first retry is attempt
    /// 2). Exponential base doubling with deterministic jitter — a pure
    /// function of `(job, attempt)`, so tests can recompute the exact
    /// schedule and two same-seed chaos floods back off identically:
    /// `base * 2^(attempt-2) * (1 + u*0.25)` with `u` drawn from an rng
    /// seeded by the job id and attempt.
    pub fn delay_for(&self, job: u64, attempt: u32) -> Duration {
        let exp = attempt.saturating_sub(2).min(16);
        let base = self.backoff.saturating_mul(1u32 << exp);
        let mut rng = Rng::new(
            0x1BAD_B002u64 ^ job.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((attempt as u64) << 32),
        );
        let jitter = base.mul_f64(rng.unit_f64() * 0.25);
        base.saturating_add(jitter)
    }
}

/// Per-job execution limits and retry policy. `Default` means
/// "unlimited, no retry" — a job submitted with the default spec
/// inherits [`ExecutorConfig::default_spec`] instead.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobSpec {
    /// Wall-clock budget from submission, enforced cooperatively at
    /// dispatch boundaries (a job between dispatches — e.g. inside one
    /// long leaf frame — overruns until its next boundary). Retries do
    /// *not* extend the deadline.
    pub deadline: Option<Duration>,
    /// Dispatch budget per attempt (frame entries through
    /// `Machine::on_dispatch`), a deterministic stand-in for CPU time.
    pub fuel_budget: Option<u64>,
    /// Cap on simultaneously live closures in the job's arena.
    pub max_live_closures: Option<usize>,
    pub retry: RetryPolicy,
}

impl JobSpec {
    pub fn validate(&self) -> Result<()> {
        if let Some(d) = self.deadline {
            if d.is_zero() {
                bail!("job spec: deadline must be > 0");
            }
        }
        if let Some(f) = self.fuel_budget {
            if f == 0 {
                bail!("job spec: fuel_budget must be >= 1 (got 0)");
            }
        }
        if let Some(c) = self.max_live_closures {
            if c == 0 {
                bail!("job spec: max_live_closures must be >= 1 (got 0)");
            }
        }
        if self.retry.max_attempts == 0 {
            bail!("job spec: retry.max_attempts must be >= 1 (got 0)");
        }
        if self.retry.max_attempts > MAX_RETRY_ATTEMPTS {
            bail!(
                "job spec: retry.max_attempts = {} exceeds the supported maximum of {MAX_RETRY_ATTEMPTS}",
                self.retry.max_attempts
            );
        }
        if self.retry.backoff > MAX_RETRY_BACKOFF {
            bail!(
                "job spec: retry.backoff = {:?} exceeds the supported maximum of {MAX_RETRY_BACKOFF:?}",
                self.retry.backoff
            );
        }
        Ok(())
    }
}

/// Executor-level configuration: the worker-pool knobs ([`WsConfig`])
/// plus the job-lifecycle knobs layered on top.
#[derive(Clone, Debug)]
pub struct ExecutorConfig {
    /// Worker pool shape (worker count, steal attempts).
    pub ws: WsConfig,
    /// Jobs allowed to run concurrently; excess submissions queue FIFO.
    pub max_active_jobs: usize,
    /// Spawn budget per job: once a job's outstanding-task count exceeds
    /// this, its new spawns overflow into its round-robin injector lane
    /// instead of the spawning worker's deque (fairness backpressure).
    pub max_inflight_per_job: usize,
    /// Shards in each job's closure arena (rounded up to a power of two).
    pub arena_shards: usize,
    /// Bound on the admission FIFO: submissions past it are shed with a
    /// structured [`JobErrorKind::Shed`] error instead of queuing
    /// unboundedly. 0 = shed as soon as the active set is full.
    pub max_queued_jobs: usize,
    /// Spec substituted for jobs submitted with `JobSpec::default()`.
    pub default_spec: JobSpec,
    /// Deterministic fault injection. `None` falls back to the
    /// `BOMBYX_CHAOS=<seed>` environment variable at [`Executor::new`];
    /// pin `Some(FaultPlan::disabled())` to stay clean regardless.
    pub fault: Option<FaultPlan>,
    /// Native-tier (JIT) selection for this executor's jobs. `None`
    /// falls back to the `BOMBYX_JIT` / `BOMBYX_JIT_THRESHOLD`
    /// environment defaults; pin
    /// `Some(crate::exec::jit::JitConfig::disabled())` to stay on the
    /// interpreter regardless.
    pub jit: Option<crate::exec::jit::JitConfig>,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            ws: WsConfig::default(),
            max_active_jobs: 64,
            max_inflight_per_job: 4096,
            arena_shards: 64,
            max_queued_jobs: 4096,
            default_spec: JobSpec::default(),
            fault: None,
            jit: None,
        }
    }
}

/// Hard sanity bounds: construction fails loudly instead of letting a
/// zero or absurd value panic deep inside worker spawn or arena setup.
const MAX_WORKERS: usize = 1024;
const MAX_ARENA_SHARDS: usize = 1 << 16;
const MAX_INFLIGHT: usize = 1 << 30;
const MAX_QUEUED_JOBS: usize = 1 << 24;

impl ExecutorConfig {
    /// Validate before any thread or arena is created.
    pub fn validate(&self) -> Result<()> {
        if self.ws.workers == 0 {
            bail!("executor config: workers must be >= 1 (got 0)");
        }
        if self.ws.workers > MAX_WORKERS {
            bail!(
                "executor config: workers = {} exceeds the supported maximum of {MAX_WORKERS}",
                self.ws.workers
            );
        }
        if self.arena_shards == 0 {
            bail!("executor config: arena_shards must be >= 1 (got 0)");
        }
        if self.arena_shards > MAX_ARENA_SHARDS {
            bail!(
                "executor config: arena_shards = {} exceeds the supported maximum of {MAX_ARENA_SHARDS}",
                self.arena_shards
            );
        }
        if self.max_active_jobs == 0 {
            bail!("executor config: max_active_jobs must be >= 1 (got 0)");
        }
        if self.max_inflight_per_job == 0 {
            bail!("executor config: max_inflight_per_job must be >= 1 (got 0)");
        }
        if self.max_inflight_per_job > MAX_INFLIGHT {
            bail!(
                "executor config: max_inflight_per_job = {} exceeds the supported maximum of {MAX_INFLIGHT}",
                self.max_inflight_per_job
            );
        }
        if self.max_queued_jobs > MAX_QUEUED_JOBS {
            bail!(
                "executor config: max_queued_jobs = {} exceeds the supported maximum of {MAX_QUEUED_JOBS}",
                self.max_queued_jobs
            );
        }
        if let Err(e) = self.default_spec.validate() {
            bail!("executor config: default_spec: {e}");
        }
        if let Some(f) = &self.fault {
            f.validate()?;
            if let Some((wid, _)) = f.kill_worker {
                if wid >= self.ws.workers {
                    bail!(
                        "executor config: fault.kill_worker = {wid} out of range for {} workers",
                        self.ws.workers
                    );
                }
            }
        }
        Ok(())
    }
}

/// Identity of a submitted job (monotonic per executor).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job#{}", self.0)
    }
}

/// A unit of work for the executor: a compiled kernel program
/// (session-cached `Arc` — many jobs can share one program), a memory
/// image, the root spawn, and the execution limits.
pub struct Job {
    pub kernels: Arc<KernelProgram>,
    pub memory: SharedMemory,
    pub entry: String,
    pub args: Vec<Value>,
    pub xla_sink: Box<dyn XlaSink>,
    pub spec: JobSpec,
}

impl Job {
    /// A job with no xla sink (programs without `extern xla`) and the
    /// executor's default spec.
    pub fn new(
        kernels: Arc<KernelProgram>,
        memory: SharedMemory,
        entry: &str,
        args: &[Value],
    ) -> Job {
        Job {
            kernels,
            memory,
            entry: entry.to_string(),
            args: args.to_vec(),
            xla_sink: Box::new(super::NoXlaSink),
            spec: JobSpec::default(),
        }
    }

    pub fn with_spec(mut self, spec: JobSpec) -> Job {
        self.spec = spec;
        self
    }
}

/// Per-job atomic counters, rolled into a [`WsStats`] snapshot at
/// completion (workers from every job update these concurrently).
#[derive(Default)]
pub(crate) struct JobCounters {
    pub(crate) tasks_run: AtomicU64,
    pub(crate) steals: AtomicU64,
    pub(crate) closures_made: AtomicU64,
    pub(crate) xla_batches: AtomicU64,
    pub(crate) xla_tasks: AtomicU64,
    pub(crate) instrs: AtomicU64,
}

/// Everything the workers need to run one job's tasks. Tasks carry an
/// `Arc<JobState>`, so a stolen task brings its whole job context with
/// it and stealing stays job-oblivious.
pub(crate) struct JobState {
    pub(crate) id: JobId,
    /// Root entry task name — the job's display name in traces/metrics.
    pub(crate) entry: String,
    pub(crate) kernels: Arc<KernelProgram>,
    /// Native-tier handle shared by every worker running this job
    /// (`None` when the JIT is disabled or unavailable). Resolved once at
    /// submission; the underlying compiled code is interned per kernel
    /// program, so jobs sharing a program share compiled artifacts.
    pub(crate) jit: Option<Arc<crate::exec::jit::JitTier>>,
    pub(crate) memory: Arc<SharedMemory>,
    /// Per-job closure arena: cancellation sweeps it in one clear, and
    /// one job's closure footprint is invisible to every other job.
    pub(crate) registry: Registry,
    pub(crate) spec: JobSpec,
    /// Root task identity, kept so a retry can re-materialize the root
    /// spawn. Retries re-run on the job's (possibly mutated) memory
    /// image — corpus kernels overwrite their outputs, so this is
    /// idempotent for them; jobs that fold into memory should not retry.
    root_fid: FuncId,
    root_args: Vec<Value>,
    /// Absolute deadline, fixed at submission (retries don't extend it).
    deadline_at: Option<Instant>,
    /// Tasks created but not yet finished; seeded at 1 for the root.
    /// Reaching zero completes the job (closures only count once fired).
    pub(crate) pending: AtomicU64,
    /// Dispatch-boundary abort flag: set by cancellation, job failure,
    /// and retry arming; workers discard the job's queued tasks at pop
    /// and unwind running ones at the next dispatch. Cleared when a
    /// retry re-arms the job.
    aborted: AtomicBool,
    /// Sticky user-cancel flag ([`JobHandle::cancel`] only): unlike
    /// `aborted` it survives retry re-arming, so a cancelled job can
    /// never be resurrected by its retry policy.
    user_cancelled: AtomicBool,
    /// Current attempt, 1-based.
    attempt: AtomicU32,
    /// Armed by [`fail_job`] when a retryable error should re-run the
    /// job; consumed by `complete` once the attempt's tasks drain.
    retry_pending: AtomicBool,
    /// Per-attempt dispatch count: the fuel meter and the fault clock.
    dispatches: AtomicU64,
    /// Fast gate for the metered dispatch path (deadline, fuel, or
    /// armed faults) — one relaxed load per dispatch when clean.
    metered: AtomicBool,
    /// This attempt's injected fault: 0 none / 1 panic / 2 transient,
    /// firing at fault-clock tick `fault_at`.
    fault_kind: AtomicU8,
    fault_at: AtomicU64,
    /// Injected micro-delay: sleep `delay_us` every `delay_every` ticks.
    delay_every: AtomicU64,
    delay_us: AtomicU64,
    /// Instances of this job's `extern xla` tasks awaiting batch flush.
    pub(crate) xla_queue: Mutex<Vec<(FuncId, Vec<Value>, Cont)>>,
    pub(crate) xla_sink: Box<dyn XlaSink>,
    pub(crate) counters: JobCounters,
    pub(crate) result: Mutex<Option<Value>>,
    pub(crate) error: Mutex<Option<JobError>>,
    /// One-shot claim on the terminal-state classification
    /// (completed/failed/cancelled): the *first* of `fail_job`,
    /// `JobHandle::cancel`, or `complete` to flip this counts the job,
    /// so lifetime aggregates add up even when a job fails or is
    /// cancelled long before its task graph drains (or never drains —
    /// the executor-drop path).
    classified: AtomicBool,
    /// One-shot claim on rolling the per-job counters into the executor
    /// totals (normally at `complete`, else at executor drop).
    counters_rolled: AtomicBool,
    /// Set by the worker that dispatches the job's first task (trace
    /// milestone).
    pub(crate) first_dispatched: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
    submitted_at: Instant,
    completed_at: Mutex<Option<Instant>>,
}

impl JobState {
    #[inline]
    pub(crate) fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::Relaxed)
    }

    /// Record the first error and abort the rest of the job (workers
    /// discard the job's remaining tasks at dispatch boundaries).
    pub(crate) fn fail(&self, err: JobError) {
        let mut slot = plock(&self.error);
        if slot.is_none() {
            *slot = Some(err);
        }
        drop(slot);
        self.aborted.store(true, Ordering::SeqCst);
    }

    /// Arm one attempt's fault schedule and reset its meters.
    pub(crate) fn arm_faults(&self, faults: JobFaults) {
        let (kind, at) = match faults.fault {
            Some((InjectedFault::Panic, at)) => (1u8, at),
            Some((InjectedFault::Transient, at)) => (2u8, at),
            None => (0, 0),
        };
        self.fault_kind.store(kind, Ordering::SeqCst);
        self.fault_at.store(at, Ordering::SeqCst);
        let (every, us) = faults.delay.unwrap_or((0, 0));
        self.delay_every.store(every, Ordering::SeqCst);
        self.delay_us.store(us, Ordering::SeqCst);
        self.dispatches.store(0, Ordering::SeqCst);
        let metered =
            self.deadline_at.is_some() || self.spec.fuel_budget.is_some() || faults.armed();
        self.metered.store(metered, Ordering::SeqCst);
    }

    #[inline]
    pub(crate) fn metered(&self) -> bool {
        self.metered.load(Ordering::Relaxed)
    }

    /// Advance the per-attempt fault clock; returns the 1-based tick.
    #[inline]
    pub(crate) fn fault_tick(&self) -> u64 {
        self.dispatches.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub(crate) fn injected_fault(&self, tick: u64) -> Option<InjectedFault> {
        let at = self.fault_at.load(Ordering::Relaxed);
        if at == 0 || tick != at {
            return None;
        }
        match self.fault_kind.load(Ordering::Relaxed) {
            1 => Some(InjectedFault::Panic),
            2 => Some(InjectedFault::Transient),
            _ => None,
        }
    }

    pub(crate) fn injected_delay(&self, tick: u64) -> Option<u64> {
        let every = self.delay_every.load(Ordering::Relaxed);
        if every != 0 && tick % every == 0 {
            Some(self.delay_us.load(Ordering::Relaxed))
        } else {
            None
        }
    }

    pub(crate) fn deadline_at(&self) -> Option<Instant> {
        self.deadline_at
    }

    fn snapshot_stats(&self) -> WsStats {
        let c = &self.counters;
        WsStats {
            tasks_run: c.tasks_run.load(Ordering::Relaxed),
            steals: c.steals.load(Ordering::Relaxed),
            closures_made: c.closures_made.load(Ordering::Relaxed),
            max_live_closures: self.registry.live_peak() as u64,
            xla_batches: c.xla_batches.load(Ordering::Relaxed),
            xla_tasks: c.xla_tasks.load(Ordering::Relaxed),
            instrs: c.instrs.load(Ordering::Relaxed),
        }
    }
}

/// Lifetime aggregates across the executor's jobs. Job-level counters
/// (`tasks_run` …) roll in when a job reaches the end of its lifecycle,
/// so a snapshot taken mid-flight undercounts by the in-flight jobs.
#[derive(Clone, Debug, Default)]
pub struct ExecutorStats {
    pub jobs_submitted: u64,
    /// Jobs that delivered a root result with no error.
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub jobs_cancelled: u64,
    /// Attempt re-runs scheduled by retry policies (a job retried twice
    /// counts twice).
    pub jobs_retried: u64,
    /// Submissions rejected by the bounded admission queue.
    pub jobs_shed: u64,
    /// Worker threads the supervisor replaced after an uncaught death.
    pub workers_respawned: u64,
    pub tasks_run: u64,
    pub steals: u64,
    pub closures_made: u64,
    pub xla_batches: u64,
    pub xla_tasks: u64,
    pub instrs: u64,
}

#[derive(Default)]
struct Totals {
    jobs_submitted: AtomicU64,
    jobs_completed: AtomicU64,
    jobs_failed: AtomicU64,
    jobs_cancelled: AtomicU64,
    jobs_retried: AtomicU64,
    jobs_shed: AtomicU64,
    workers_respawned: AtomicU64,
    tasks_run: AtomicU64,
    steals: AtomicU64,
    closures_made: AtomicU64,
    xla_batches: AtomicU64,
    xla_tasks: AtomicU64,
    instrs: AtomicU64,
}

/// Round-robin injection queues, one lane per job: a lane is created on
/// first push and dropped when drained, and `pop` rotates across lanes
/// so every active job's injected work makes progress regardless of how
/// much any single job floods in.
struct Injector {
    lanes: VecDeque<(JobId, VecDeque<WsTask>)>,
    total: usize,
}

impl Injector {
    fn new() -> Injector {
        Injector { lanes: VecDeque::new(), total: 0 }
    }

    fn push(&mut self, task: WsTask) {
        let id = task.job.id;
        match self.lanes.iter_mut().find(|(lid, _)| *lid == id) {
            Some((_, lane)) => lane.push_back(task),
            None => self.lanes.push_back((id, VecDeque::from([task]))),
        }
        self.total += 1;
    }

    /// Take one task, round-robin over lanes.
    fn pop(&mut self) -> Option<WsTask> {
        let (id, mut lane) = self.lanes.pop_front()?;
        let task = lane.pop_front();
        if !lane.is_empty() {
            self.lanes.push_back((id, lane));
        }
        debug_assert!(task.is_some(), "injector lanes are never left empty");
        if task.is_some() {
            self.total -= 1;
        }
        task
    }

    /// Remove every task of one job (cancellation).
    fn purge(&mut self, id: JobId) -> Vec<WsTask> {
        let mut out = Vec::new();
        let lanes = std::mem::take(&mut self.lanes);
        for (lid, mut lane) in lanes {
            if lid == id {
                out.extend(lane.drain(..));
            } else {
                self.lanes.push_back((lid, lane));
            }
        }
        self.total -= out.len();
        out
    }

    fn drain_all(&mut self) -> Vec<WsTask> {
        let mut out = Vec::new();
        for (_, mut lane) in std::mem::take(&mut self.lanes) {
            out.extend(lane.drain(..));
        }
        self.total = 0;
        out
    }
}

/// Admission control: the active set plus the FIFO of jobs waiting for a
/// slot (each queued entry parks its un-injected root task).
struct Admission {
    active: Vec<Arc<JobState>>,
    queued: VecDeque<(Arc<JobState>, WsTask)>,
}

/// Shared fault-injection state derived from the configured
/// [`FaultPlan`]: the one-shot worker-kill arm and its steal-attempt
/// clock live here, everything per-job is armed into `JobState`.
pub(crate) struct FaultState {
    pub(crate) plan: FaultPlan,
    /// One-shot: the first worker to satisfy `plan.kill_worker` claims
    /// this, so the respawned worker does not die again.
    pub(crate) kill_armed: AtomicBool,
    /// Steal attempts observed by the kill-target worker.
    pub(crate) steal_clock: AtomicU64,
}

/// State shared between the executor handle and its resident workers.
pub(crate) struct ExecShared {
    pub(crate) config: ExecutorConfig,
    /// Per-worker lock-free deques (owner hot end, thief cold end).
    pub(crate) deques: Vec<Deque<WsTask>>,
    injector: Mutex<Injector>,
    /// Mirror of the injector's total length, maintained under its lock:
    /// lets the worker loop skip the mutex when nothing is injected.
    injected: AtomicUsize,
    admission: Mutex<Admission>,
    pub(crate) shutdown: AtomicBool,
    /// Total queued xla instances across jobs (gates the flush scan).
    pub(crate) xla_pending: AtomicU64,
    /// Parked-worker wakeup.
    pub(crate) idle_lock: Mutex<()>,
    pub(crate) idle_cv: Condvar,
    /// Number of workers currently parked (gates notify syscalls).
    pub(crate) idle_workers: AtomicU64,
    /// Per-worker "inside a steal attempt" flags — a thief may hold a
    /// stale buffer pointer only while its flag is up, which is what
    /// makes quiescent retired-buffer reclamation safe.
    pub(crate) in_steal: Vec<AtomicBool>,
    /// Derived fault-injection state, when a plan is armed.
    pub(crate) fault: Option<FaultState>,
    /// Supervisor wakeup: worker deaths and newly scheduled retries
    /// notify here; otherwise the supervisor ticks every 25ms.
    sup_lock: Mutex<()>,
    pub(crate) sup_cv: Condvar,
    /// Worker ids whose threads died (uncaught panic); the supervisor
    /// drains this and respawns each on its original deque index.
    pub(crate) dead_workers: Mutex<Vec<usize>>,
    /// Jobs awaiting a retry dispatch, with their due time.
    retries: Mutex<Vec<(Instant, Arc<JobState>)>>,
    /// Join handles indexed by worker id; `None` while being respawned
    /// (the supervisor joins the dead handle outside this lock).
    worker_handles: Mutex<Vec<Option<JoinHandle<()>>>>,
    totals: Totals,
}

impl ExecShared {
    #[inline]
    pub(crate) fn notify_if_idle(&self) {
        if self.idle_workers.load(Ordering::Relaxed) > 0 {
            self.idle_cv.notify_one();
        }
    }

    /// Enqueue into the task's per-job injector lane.
    pub(crate) fn inject(&self, task: WsTask) {
        {
            let mut inj = plock(&self.injector);
            inj.push(task);
            self.injected.store(inj.total, Ordering::SeqCst);
        }
        self.notify_if_idle();
    }

    /// Dequeue the next injected task, round-robin across job lanes.
    pub(crate) fn pop_injected(&self) -> Option<WsTask> {
        if self.injected.load(Ordering::SeqCst) == 0 {
            return None;
        }
        let mut inj = plock(&self.injector);
        let task = inj.pop();
        self.injected.store(inj.total, Ordering::SeqCst);
        task
    }

    /// Snapshot of the active set (xla flush iterates it).
    pub(crate) fn active_jobs(&self) -> Vec<Arc<JobState>> {
        plock(&self.admission).active.clone()
    }

    /// Free retired deque buffers if the executor is fully quiescent: no
    /// job active or queued, nothing injected, every deque empty, and no
    /// thief mid-steal. A thief entering `steal` *after* this check loads
    /// the current buffer pointer (never a retired one) and bails on
    /// `top >= bottom` before touching it, so only a thief already
    /// inside a steal — excluded by the `in_steal` flags — could hold a
    /// retired pointer. (Same formal-memory-model caveat as documented
    /// in [`super::deque`]: these are Relaxed/Acquire observations, not
    /// a proof against arbitrarily stale loads.)
    pub(crate) fn try_reclaim(&self) {
        let adm = plock(&self.admission);
        if !adm.active.is_empty() || !adm.queued.is_empty() {
            return;
        }
        if self.injected.load(Ordering::SeqCst) != 0 {
            return;
        }
        if self.deques.iter().any(|d| d.len_hint() != 0) {
            return;
        }
        if self.in_steal.iter().any(|f| f.load(Ordering::SeqCst)) {
            return;
        }
        for d in &self.deques {
            d.free_retired();
        }
        drop(adm);
    }

    fn stats(&self) -> ExecutorStats {
        let t = &self.totals;
        ExecutorStats {
            jobs_submitted: t.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: t.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: t.jobs_failed.load(Ordering::Relaxed),
            jobs_cancelled: t.jobs_cancelled.load(Ordering::Relaxed),
            jobs_retried: t.jobs_retried.load(Ordering::Relaxed),
            jobs_shed: t.jobs_shed.load(Ordering::Relaxed),
            workers_respawned: t.workers_respawned.load(Ordering::Relaxed),
            tasks_run: t.tasks_run.load(Ordering::Relaxed),
            steals: t.steals.load(Ordering::Relaxed),
            closures_made: t.closures_made.load(Ordering::Relaxed),
            xla_batches: t.xla_batches.load(Ordering::Relaxed),
            xla_tasks: t.xla_tasks.load(Ordering::Relaxed),
            instrs: t.instrs.load(Ordering::Relaxed),
        }
    }
}

/// Decrement a job's outstanding-task count; the thread that takes it to
/// zero completes the job. Every task accounted in `pending` must funnel
/// through here exactly once — executed, discarded on abort, purged from
/// the injector, or drained from the xla queue.
pub(crate) fn finish_one(shared: &ExecShared, job: &Arc<JobState>) {
    if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        complete(shared, job);
    }
}

/// Terminal states a job is counted under, exactly once.
#[derive(Clone, Copy)]
enum Terminal {
    Completed,
    Failed,
    Cancelled,
}

/// Bump the executor total (and its metrics-registry mirror) for one
/// job's terminal state. Callers must hold the `classified` claim.
fn record_terminal(shared: &ExecShared, t: Terminal) {
    let (total, metric) = match t {
        Terminal::Completed => (&shared.totals.jobs_completed, "ws.jobs_completed"),
        Terminal::Failed => (&shared.totals.jobs_failed, "ws.jobs_failed"),
        Terminal::Cancelled => (&shared.totals.jobs_cancelled, "ws.jobs_cancelled"),
    };
    total.fetch_add(1, Ordering::Relaxed);
    obs::metrics::counter_add(metric, 1);
}

/// Record a job failure. If the error kind is retryable under the job's
/// policy (and the job still has attempts, was not user-cancelled, and
/// the executor is not shutting down), the failure arms a retry instead
/// of becoming terminal: the current attempt is aborted, its tasks
/// drain, and `complete` hands the job to the supervisor for a backed-
/// off re-run. Otherwise the first error wins, the job is aborted, and
/// it is counted failed *now* — not when (or if) its task graph finishes
/// draining — so lifetime aggregates include jobs the pool never
/// completed.
pub(crate) fn fail_job(shared: &ExecShared, job: &JobState, err: JobError) {
    let kind = err.kind();
    let policy = &job.spec.retry;
    let retryable =
        kind.retryable() || (policy.retry_on_panic && kind == JobErrorKind::Panicked);
    let retry = retryable
        && job.attempt.load(Ordering::SeqCst) < policy.max_attempts
        && !job.user_cancelled.load(Ordering::SeqCst)
        && !shared.shutdown.load(Ordering::SeqCst);
    if retry {
        let armed = {
            // A hard error recorded by another task outranks the retry.
            let slot = plock(&job.error);
            slot.is_none()
        };
        if armed {
            job.retry_pending.store(true, Ordering::SeqCst);
            job.aborted.store(true, Ordering::SeqCst);
            if obs::trace_enabled() {
                obs::trace::async_instant(
                    "retry-armed",
                    "job",
                    job.id.0,
                    vec![("kind", ArgVal::Str(kind.tag().to_string()))],
                );
            }
            return;
        }
    }
    job.fail(err);
    job.retry_pending.store(false, Ordering::SeqCst);
    if !job.classified.swap(true, Ordering::SeqCst) {
        record_terminal(shared, Terminal::Failed);
    }
}

/// Roll one job's counters into the executor lifetime totals.
fn roll_counters(shared: &ExecShared, s: &WsStats) {
    let t = &shared.totals;
    t.tasks_run.fetch_add(s.tasks_run, Ordering::Relaxed);
    t.steals.fetch_add(s.steals, Ordering::Relaxed);
    t.closures_made.fetch_add(s.closures_made, Ordering::Relaxed);
    t.xla_batches.fetch_add(s.xla_batches, Ordering::Relaxed);
    t.xla_tasks.fetch_add(s.xla_tasks, Ordering::Relaxed);
    t.instrs.fetch_add(s.instrs, Ordering::Relaxed);
}

/// Hand a drained, retry-armed job to the supervisor: re-arm its
/// per-attempt state (fault schedule, meters, abort flag) and enqueue it
/// with its deterministic backoff due-time.
fn schedule_retry(shared: &ExecShared, job: &Arc<JobState>) {
    let next = job.attempt.fetch_add(1, Ordering::SeqCst) + 1;
    // Discard any partial root result of the failed attempt.
    *plock(&job.result) = None;
    let faults = shared
        .fault
        .as_ref()
        .map(|f| f.plan.for_job(job.id.0, next))
        .unwrap_or_default();
    job.arm_faults(faults);
    job.pending.store(1, Ordering::SeqCst);
    job.aborted.store(false, Ordering::SeqCst);
    let delay = job.spec.retry.delay_for(job.id.0, next);
    shared.totals.jobs_retried.fetch_add(1, Ordering::Relaxed);
    obs::metrics::counter_add("ws.jobs_retried", 1);
    if obs::trace_enabled() {
        obs::trace::async_instant(
            "retry",
            "job",
            job.id.0,
            vec![
                ("attempt", ArgVal::I64(next as i64)),
                ("delay_ms", ArgVal::F64(delay.as_secs_f64() * 1e3)),
            ],
        );
    }
    plock(&shared.retries).push((Instant::now() + delay, Arc::clone(job)));
    shared.sup_cv.notify_all();
}

/// End of one attempt's task drain. Either the job retries (armed by
/// [`fail_job`], not overtaken by a hard error, cancel, or shutdown) —
/// or this is the end of the job's lifecycle: sweep its closure arena,
/// roll its counters into the executor totals, free its admission slot
/// (admitting the next queued job), wake joiners, and try idle
/// reclamation.
fn complete(shared: &ExecShared, job: &Arc<JobState>) {
    // Reclaims every closure an aborted attempt left unfired; a no-op
    // for a cleanly drained graph. Runs strictly after the attempt's
    // last task (pending just hit zero), so nothing can still resolve
    // handles. A retry re-inserts from scratch.
    job.registry.clear();

    if job.retry_pending.swap(false, Ordering::SeqCst) && plock(&job.error).is_none() {
        if job.user_cancelled.load(Ordering::SeqCst) {
            // Cancelled while the retry was pending: terminal after all
            // (cancel() already classified the job as cancelled).
            job.fail(JobError::cancelled(job.id));
        } else if shared.shutdown.load(Ordering::SeqCst) {
            job.fail(JobError::internal(format!(
                "executor shut down before {} could retry",
                job.id
            )));
        } else {
            // Not terminal: the job keeps its admission slot and waits
            // out its backoff on the supervisor's timer.
            schedule_retry(shared, job);
            return;
        }
    }

    if !job.counters_rolled.swap(true, Ordering::SeqCst) {
        roll_counters(shared, &job.snapshot_stats());
    }
    // Failed and cancelled jobs were classified when `fail_job` /
    // `JobHandle::cancel` ran; everything still unclaimed here finished
    // cleanly (or was cancelled after delivering its result, which
    // counts as completed).
    if !job.classified.swap(true, Ordering::SeqCst) {
        let failed = plock(&job.error).is_some();
        let delivered = plock(&job.result).is_some();
        let terminal = if failed {
            Terminal::Failed
        } else if !delivered && job.aborted.load(Ordering::SeqCst) {
            Terminal::Cancelled
        } else {
            Terminal::Completed
        };
        record_terminal(shared, terminal);
    }
    let now = Instant::now();
    *plock(&job.completed_at) = Some(now);
    let latency = now.duration_since(job.submitted_at);
    obs::metrics::observe_ms("ws.job.latency_ms", latency);
    if obs::trace_enabled() {
        obs::trace::async_end(
            job.entry.clone(),
            "job",
            job.id.0,
            vec![("latency_ms", ArgVal::F64(latency.as_secs_f64() * 1e3))],
        );
    }

    // Free the admission slot; admit the longest-waiting queued job.
    let next_root = {
        let mut adm = plock(&shared.admission);
        adm.active.retain(|j| j.id != job.id);
        if adm.active.len() < shared.config.max_active_jobs {
            if let Some((next, root)) = adm.queued.pop_front() {
                adm.active.push(next);
                Some(root)
            } else {
                None
            }
        } else {
            None
        }
    };
    if let Some(root) = next_root {
        if obs::trace_enabled() {
            obs::trace::async_instant("admit", "job", root.job.id.0, Vec::new());
        }
        shared.inject(root);
    }

    {
        let mut done = plock(&job.done);
        *done = true;
    }
    job.done_cv.notify_all();
    shared.try_reclaim();
}

/// Supervisor: respawns dead workers and dispatches due retries. Worker
/// deaths and new retries notify `sup_cv`; the idle tick (25ms) bounds
/// the latency of anything a notify raced past.
fn supervisor_loop(shared: &Arc<ExecShared>) {
    if obs::trace_enabled() {
        obs::trace::set_thread_name("ws-supervisor");
    }
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        respawn_dead_workers(shared);
        let next_due = pump_retries(shared);
        let wait = match next_due {
            Some(due) => due
                .saturating_duration_since(Instant::now())
                .max(Duration::from_micros(100)),
            None => Duration::from_millis(25),
        };
        let guard = plock(&shared.sup_lock);
        let _ = shared
            .sup_cv
            .wait_timeout(guard, wait)
            .unwrap_or_else(|p| p.into_inner());
    }
}

/// Respawn every worker registered dead, on its original deque index.
/// The old thread is joined first (outside the handle table's lock), so
/// at most one thread ever owns a worker id; tasks left in the dead
/// worker's deque stay stealable throughout and the respawned worker
/// resumes draining them.
fn respawn_dead_workers(shared: &Arc<ExecShared>) {
    loop {
        let wid = match plock(&shared.dead_workers).pop() {
            Some(wid) => wid,
            None => return,
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let old = plock(&shared.worker_handles)[wid].take();
        if let Some(handle) = old {
            let _ = handle.join();
        }
        shared.totals.workers_respawned.fetch_add(1, Ordering::Relaxed);
        obs::metrics::counter_add("ws.workers_respawned", 1);
        if obs::trace_enabled() {
            obs::trace::instant(
                "worker-respawn",
                "ws",
                vec![("wid", ArgVal::I64(wid as i64))],
            );
        }
        let sh = Arc::clone(shared);
        let spawned = std::thread::Builder::new()
            .name(format!("bombyx-ws-{wid}"))
            .spawn(move || worker::worker_loop(wid, sh));
        if let Ok(handle) = spawned {
            plock(&shared.worker_handles)[wid] = Some(handle);
        }
        // A failed respawn (resource exhaustion) leaves the slot empty;
        // the pool runs degraded rather than panicking the supervisor.
    }
}

/// Dispatch due retries (and finish off retries whose job was cancelled
/// or the executor shut down while they waited). Returns the earliest
/// still-pending due time.
fn pump_retries(shared: &Arc<ExecShared>) -> Option<Instant> {
    let now = Instant::now();
    let mut due_jobs = Vec::new();
    let mut next_due: Option<Instant> = None;
    {
        let mut retries = plock(&shared.retries);
        let mut i = 0;
        while i < retries.len() {
            let (due, job) = &retries[i];
            let take = *due <= now
                || job.user_cancelled.load(Ordering::SeqCst)
                || shared.shutdown.load(Ordering::SeqCst);
            if take {
                due_jobs.push(retries.swap_remove(i).1);
            } else {
                next_due = Some(next_due.map_or(*due, |d| d.min(*due)));
                i += 1;
            }
        }
    }
    for job in due_jobs {
        if job.user_cancelled.load(Ordering::SeqCst) {
            job.fail(JobError::cancelled(job.id));
            finish_one(shared, &job);
        } else if shared.shutdown.load(Ordering::SeqCst) {
            job.fail(JobError::internal(format!(
                "executor shut down before {} could retry",
                job.id
            )));
            finish_one(shared, &job);
        } else {
            if obs::trace_enabled() {
                obs::trace::async_instant(
                    "retry-dispatch",
                    "job",
                    job.id.0,
                    vec![("attempt", ArgVal::I64(job.attempt.load(Ordering::SeqCst) as i64))],
                );
            }
            let root = WsTask {
                job: Arc::clone(&job),
                task: job.root_fid,
                args: ArgList::from_slice(&job.root_args),
                cont: Cont::Root,
            };
            shared.inject(root);
        }
    }
    next_due
}

/// The resident executor: a fixed pool of worker threads draining tasks
/// from every submitted job, plus a supervisor thread for respawns and
/// retries. Dropping it shuts the pool down (in-flight jobs are failed
/// so joiners cannot hang).
pub struct Executor {
    shared: Arc<ExecShared>,
    supervisor: Option<JoinHandle<()>>,
    next_job: AtomicU64,
}

impl Executor {
    /// Validate the configuration and spawn the resident worker pool and
    /// its supervisor. When the config carries no fault plan, the
    /// `BOMBYX_CHAOS=<seed>` environment variable arms the standard
    /// chaos mix ([`FaultPlan::chaos`]).
    pub fn new(config: ExecutorConfig) -> Result<Executor> {
        config.validate()?;
        let plan = match &config.fault {
            Some(p) => Some(p.clone()),
            None => FaultPlan::from_env()?,
        };
        let workers = config.ws.workers;
        let shared = Arc::new(ExecShared {
            config,
            deques: (0..workers).map(|_| Deque::new()).collect(),
            injector: Mutex::new(Injector::new()),
            injected: AtomicUsize::new(0),
            admission: Mutex::new(Admission { active: Vec::new(), queued: VecDeque::new() }),
            shutdown: AtomicBool::new(false),
            xla_pending: AtomicU64::new(0),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            idle_workers: AtomicU64::new(0),
            in_steal: (0..workers).map(|_| AtomicBool::new(false)).collect(),
            fault: plan.map(|plan| FaultState {
                plan,
                kill_armed: AtomicBool::new(true),
                steal_clock: AtomicU64::new(0),
            }),
            sup_lock: Mutex::new(()),
            sup_cv: Condvar::new(),
            dead_workers: Mutex::new(Vec::new()),
            retries: Mutex::new(Vec::new()),
            worker_handles: Mutex::new((0..workers).map(|_| None).collect()),
            totals: Totals::default(),
        });
        let teardown = |shared: &Arc<ExecShared>| {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.idle_cv.notify_all();
            let handles: Vec<_> = plock(&shared.worker_handles)
                .iter_mut()
                .filter_map(Option::take)
                .collect();
            for t in handles {
                let _ = t.join();
            }
        };
        for wid in 0..workers {
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("bombyx-ws-{wid}"))
                .spawn(move || worker::worker_loop(wid, sh));
            match spawned {
                Ok(handle) => plock(&shared.worker_handles)[wid] = Some(handle),
                Err(e) => {
                    teardown(&shared);
                    bail!("spawning ws worker {wid}: {e}");
                }
            }
        }
        let sh = Arc::clone(&shared);
        let supervisor = match std::thread::Builder::new()
            .name("bombyx-ws-supervisor".to_string())
            .spawn(move || supervisor_loop(&sh))
        {
            Ok(handle) => Some(handle),
            Err(e) => {
                teardown(&shared);
                bail!("spawning ws supervisor: {e}");
            }
        };
        Ok(Executor { shared, supervisor, next_job: AtomicU64::new(0) })
    }

    pub fn workers(&self) -> usize {
        self.shared.deques.len()
    }

    /// Submit a job. Fails fast with a structured [`JobError`] — before
    /// consuming an admission slot — if the entry task does not exist,
    /// the (substituted) spec is invalid, or the bounded admission queue
    /// is full ([`JobErrorKind::Shed`]).
    pub fn submit(&self, job: Job) -> Result<JobHandle, JobError> {
        let Job { kernels, memory, entry, args, xla_sink, spec } = job;
        let fid = kernels
            .func_by_name(&entry)
            .ok_or_else(|| JobError::internal(format!("no task named `{entry}`")))?;
        // A default spec inherits the executor-wide default (so chaos
        // floods can set a pool-level retry policy without threading it
        // through every submit site).
        let spec = if spec == JobSpec::default() {
            self.shared.config.default_spec.clone()
        } else {
            spec
        };
        if let Err(e) = spec.validate() {
            return Err(JobError::internal(e.to_string()));
        }
        let id = JobId(self.next_job.fetch_add(1, Ordering::Relaxed));
        let deadline_at = spec.deadline.map(|d| Instant::now() + d);
        let jit = match self.shared.config.jit {
            Some(cfg) => crate::exec::jit::tier_with(&kernels, cfg),
            None => crate::exec::jit::tier_for(&kernels),
        };
        let state = Arc::new(JobState {
            id,
            entry,
            kernels,
            jit,
            memory: Arc::new(memory),
            registry: Registry::new(self.shared.config.arena_shards),
            spec,
            root_fid: fid,
            root_args: args.clone(),
            deadline_at,
            pending: AtomicU64::new(1),
            aborted: AtomicBool::new(false),
            user_cancelled: AtomicBool::new(false),
            attempt: AtomicU32::new(1),
            retry_pending: AtomicBool::new(false),
            dispatches: AtomicU64::new(0),
            metered: AtomicBool::new(false),
            fault_kind: AtomicU8::new(0),
            fault_at: AtomicU64::new(0),
            delay_every: AtomicU64::new(0),
            delay_us: AtomicU64::new(0),
            xla_queue: Mutex::new(Vec::new()),
            xla_sink,
            counters: JobCounters::default(),
            result: Mutex::new(None),
            error: Mutex::new(None),
            classified: AtomicBool::new(false),
            counters_rolled: AtomicBool::new(false),
            first_dispatched: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
            submitted_at: Instant::now(),
            completed_at: Mutex::new(None),
        });
        state.arm_faults(
            self.shared
                .fault
                .as_ref()
                .map(|f| f.plan.for_job(id.0, 1))
                .unwrap_or_default(),
        );
        let mut root = Some(WsTask {
            job: Arc::clone(&state),
            task: fid,
            args: ArgList::from_slice(&args),
            cont: Cont::Root,
        });
        enum Adm {
            Active,
            Queued,
            Shed(usize),
        }
        let decision = {
            let mut adm = plock(&self.shared.admission);
            if adm.active.len() < self.shared.config.max_active_jobs {
                adm.active.push(Arc::clone(&state));
                Adm::Active
            } else if adm.queued.len() < self.shared.config.max_queued_jobs {
                adm.queued
                    .push_back((Arc::clone(&state), root.take().expect("root built above")));
                Adm::Queued
            } else {
                Adm::Shed(adm.queued.len())
            }
        };
        if let Adm::Shed(queued) = decision {
            self.shared.totals.jobs_shed.fetch_add(1, Ordering::Relaxed);
            obs::metrics::counter_add("ws.jobs_shed", 1);
            if obs::trace_enabled() {
                obs::trace::instant("shed", "ws", vec![("job", ArgVal::I64(id.0 as i64))]);
            }
            return Err(JobError::shed(id, queued, self.shared.config.max_queued_jobs));
        }
        self.shared.totals.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        obs::metrics::counter_add("ws.jobs_submitted", 1);
        if obs::trace_enabled() {
            // Async span: the job lifecycle migrates across threads, so
            // submit→complete is a `b`/`e` pair keyed by the job id.
            obs::trace::async_begin(
                state.entry.clone(),
                "job",
                id.0,
                vec![("job", ArgVal::I64(id.0 as i64))],
            );
        }
        let admitted = matches!(decision, Adm::Active);
        if let Some(root) = root {
            self.shared.inject(root);
        }
        if obs::trace_enabled() {
            let mark = if admitted { "admit" } else { "queue" };
            obs::trace::async_instant(mark, "job", id.0, Vec::new());
        }
        Ok(JobHandle { job: state, shared: Arc::clone(&self.shared) })
    }

    /// Lifetime aggregates (completed jobs; see [`ExecutorStats`]).
    pub fn stats(&self) -> ExecutorStats {
        self.shared.stats()
    }

    /// Retired (outgrown, not yet freed) deque buffers across workers —
    /// observability for the idle-reclamation path.
    pub fn retired_buffers(&self) -> usize {
        self.shared.deques.iter().map(|d| d.retired_len()).sum()
    }

    /// Publish the lifetime aggregates into the metrics registry under
    /// their canonical `ws.*` names (authoritative snapshot — overwrites
    /// the incrementally-maintained job counts with the same values).
    /// No-op while metrics are disabled.
    pub fn publish_metrics(&self) {
        if !obs::metrics_enabled() {
            return;
        }
        let s = self.stats();
        obs::metrics::counter_set("ws.jobs_submitted", s.jobs_submitted);
        obs::metrics::counter_set("ws.jobs_completed", s.jobs_completed);
        obs::metrics::counter_set("ws.jobs_failed", s.jobs_failed);
        obs::metrics::counter_set("ws.jobs_cancelled", s.jobs_cancelled);
        obs::metrics::counter_set("ws.jobs_retried", s.jobs_retried);
        obs::metrics::counter_set("ws.jobs_shed", s.jobs_shed);
        obs::metrics::counter_set("ws.workers_respawned", s.workers_respawned);
        obs::metrics::counter_set("ws.tasks_run", s.tasks_run);
        obs::metrics::counter_set("ws.steals", s.steals);
        obs::metrics::counter_set("ws.closures_made", s.closures_made);
        obs::metrics::counter_set("ws.xla_batches", s.xla_batches);
        obs::metrics::counter_set("ws.xla_tasks", s.xla_tasks);
        obs::metrics::counter_set("ws.instrs_retired", s.instrs);
        obs::metrics::gauge_set("ws.workers", self.workers() as f64);
        obs::metrics::gauge_set("ws.retired_buffers", self.retired_buffers() as f64);
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.idle_cv.notify_all();
        self.shared.sup_cv.notify_all();
        // Supervisor first: after it joins, nothing respawns workers or
        // dispatches retries concurrently with this teardown.
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
        let handles: Vec<_> = plock(&self.shared.worker_handles)
            .iter_mut()
            .filter_map(Option::take)
            .collect();
        for t in handles {
            let _ = t.join();
        }
        // Jobs still waiting out a retry backoff: fail them (their
        // pending count is the un-injected root) *before* draining the
        // injector — their completion may admit a queued job's root.
        let waiting: Vec<Arc<JobState>> =
            plock(&self.shared.retries).drain(..).map(|(_, j)| j).collect();
        for job in waiting {
            job.fail(JobError::internal(format!(
                "executor shut down before {} could retry",
                job.id
            )));
            finish_one(&self.shared, &job);
        }
        // Workers are gone; fail whatever is still in flight so late
        // joiners see an error instead of hanging on the condvar.
        let orphans = {
            let mut inj = plock(&self.shared.injector);
            let tasks = inj.drain_all();
            self.shared.injected.store(0, Ordering::SeqCst);
            tasks
        };
        drop(orphans);
        let leftovers: Vec<Arc<JobState>> = {
            let mut adm = plock(&self.shared.admission);
            let mut jobs = std::mem::take(&mut adm.active);
            jobs.extend(adm.queued.drain(..).map(|(j, _)| j));
            jobs
        };
        for job in leftovers {
            // `fail_job` semantics (classify as failed) so drop-orphaned
            // jobs land in `jobs_failed`, and their counters roll in —
            // lifetime aggregates must add up even for jobs complete()
            // never saw.
            job.fail(JobError::internal(format!(
                "executor shut down with {} in flight",
                job.id
            )));
            if !job.classified.swap(true, Ordering::SeqCst) {
                record_terminal(&self.shared, Terminal::Failed);
            }
            if !job.counters_rolled.swap(true, Ordering::SeqCst) {
                roll_counters(&self.shared, &job.snapshot_stats());
            }
            job.registry.clear();
            if obs::trace_enabled() {
                obs::trace::async_end(
                    job.entry.clone(),
                    "job",
                    job.id.0,
                    vec![("dropped", ArgVal::I64(1))],
                );
            }
            {
                let mut done = plock(&job.done);
                *done = true;
            }
            job.done_cv.notify_all();
        }
    }
}

/// Client-side handle to a submitted job.
pub struct JobHandle {
    job: Arc<JobState>,
    shared: Arc<ExecShared>,
}

impl JobHandle {
    pub fn id(&self) -> JobId {
        self.job.id
    }

    pub fn is_finished(&self) -> bool {
        *plock(&self.job.done)
    }

    /// Block until the job reaches the end of its lifecycle (result,
    /// error, or cancellation drained — across every retry attempt).
    pub fn wait(&self) {
        let mut done = plock(&self.job.done);
        while !*done {
            done = self
                .job
                .done_cv
                .wait(done)
                .unwrap_or_else(|p| p.into_inner());
        }
        drop(done);
        self.shared.try_reclaim();
    }

    /// Wait and consume the handle: root result, final memory image, and
    /// this job's stats. The memory is the `Arc` shared with any tasks
    /// that ran it — sole ownership returns once the executor (or at
    /// least this job's last task) is gone. Failures are structured
    /// [`JobError`]s; `?` into an `anyhow::Result` keeps working.
    pub fn join(self) -> Result<(Value, Arc<SharedMemory>, WsStats), JobError> {
        self.wait();
        let stats = self.job.snapshot_stats();
        if let Some(err) = plock(&self.job.error).take() {
            return Err(err);
        }
        let result = plock(&self.job.result).take();
        match result {
            Some(value) => Ok((value, Arc::clone(&self.job.memory), stats)),
            None if self.job.is_aborted() => Err(JobError::cancelled(self.job.id)),
            None => Err(JobError::internal("task graph drained without a root result")),
        }
    }

    /// The terminal error kind, if the job has failed (readable without
    /// consuming the handle — the flood report's outcome breakdown).
    pub fn error_kind(&self) -> Option<JobErrorKind> {
        plock(&self.job.error).as_ref().map(|e| e.kind())
    }

    /// Attempts started so far (1 = never retried).
    pub fn attempts(&self) -> u32 {
        self.job.attempt.load(Ordering::SeqCst)
    }

    /// Cooperatively cancel the job. Queued-but-unstarted jobs complete
    /// immediately; in-flight jobs stop at the next dispatch boundary of
    /// each of their tasks, and the job's injector lane, xla queue, and
    /// closure arena are reclaimed. Cancellation is sticky across
    /// retries: a job waiting out a retry backoff is finished off by the
    /// supervisor instead of re-running. A job may still complete
    /// normally if its root result was already delivered.
    pub fn cancel(&self) {
        if self.job.user_cancelled.swap(true, Ordering::SeqCst) {
            return;
        }
        self.job.aborted.store(true, Ordering::SeqCst);
        // Count the cancellation *now* (unless the root result was
        // already delivered — that job still completes normally), so
        // executor totals include jobs whose graphs take a while to
        // drain, or never do.
        let delivered = plock(&self.job.result).is_some();
        if !delivered && !self.job.classified.swap(true, Ordering::SeqCst) {
            record_terminal(&self.shared, Terminal::Cancelled);
        }
        if obs::trace_enabled() {
            obs::trace::async_instant("cancel", "job", self.job.id.0, Vec::new());
        }
        // Still parked in the admission queue? Its root never ran: drop
        // the parked task and retire the job's only pending count.
        let parked = {
            let mut adm = plock(&self.shared.admission);
            adm.queued
                .iter()
                .position(|(j, _)| j.id == self.job.id)
                .and_then(|pos| adm.queued.remove(pos))
        };
        if let Some((job, root)) = parked {
            drop(root);
            finish_one(&self.shared, &job);
            return;
        }
        // In flight: purge the injector lane and the xla queue — workers
        // discard everything else at dispatch boundaries.
        let purged = {
            let mut inj = plock(&self.shared.injector);
            let tasks = inj.purge(self.job.id);
            self.shared.injected.store(inj.total, Ordering::SeqCst);
            tasks
        };
        for task in purged {
            let job = Arc::clone(&task.job);
            drop(task);
            finish_one(&self.shared, &job);
        }
        let drained: Vec<_> = {
            let mut q = plock(&self.job.xla_queue);
            q.drain(..).collect()
        };
        if !drained.is_empty() {
            self.shared.xla_pending.fetch_sub(drained.len() as u64, Ordering::SeqCst);
            let n = drained.len();
            drop(drained);
            for _ in 0..n {
                finish_one(&self.shared, &self.job);
            }
        }
        self.shared.idle_cv.notify_all();
        // Wake the supervisor so a retry-parked job finishes without
        // waiting out its backoff.
        self.shared.sup_cv.notify_all();
    }

    /// Live closures in this job's arena (0 after completion or a
    /// drained cancellation).
    pub fn live_closures(&self) -> usize {
        self.job.registry.live()
    }

    /// Stats snapshot (mid-flight snapshots are racy but monotonic).
    pub fn stats(&self) -> WsStats {
        self.job.snapshot_stats()
    }

    /// Submission-to-completion latency, once finished.
    pub fn latency(&self) -> Option<Duration> {
        plock(&self.job.completed_at).map(|t| t.duration_since(self.job.submitted_at))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_valid() {
        assert!(ExecutorConfig::default().validate().is_ok());
    }

    #[test]
    fn injector_empty_bookkeeping() {
        // Lane rotation under real tasks is covered by the fairness test
        // in rust/tests/executor_tests.rs; the empty-state invariants are
        // checkable without a job.
        let mut inj = Injector::new();
        assert!(inj.pop().is_none());
        assert_eq!(inj.total, 0);
        assert!(inj.drain_all().is_empty());
    }

    #[test]
    fn retry_backoff_is_deterministic_and_exponential() {
        let p = RetryPolicy {
            max_attempts: 6,
            backoff: Duration::from_millis(10),
            retry_on_panic: false,
        };
        for attempt in 2..=6u32 {
            // Pure function of (job, attempt).
            assert_eq!(p.delay_for(7, attempt), p.delay_for(7, attempt));
            // Doubling base, jitter within +25%.
            let base = Duration::from_millis(10) * (1u32 << (attempt - 2));
            let d = p.delay_for(7, attempt);
            assert!(d >= base, "attempt {attempt}: {d:?} < base {base:?}");
            assert!(d <= base.mul_f64(1.25), "attempt {attempt}: {d:?} over jitter cap");
        }
        // Different jobs jitter differently somewhere across a few ids.
        assert!((0..16u64).any(|j| p.delay_for(j, 2) != p.delay_for(j + 16, 2)));
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        let spec_with = |f: fn(&mut JobSpec)| {
            let mut s = JobSpec::default();
            f(&mut s);
            s
        };
        let cases: Vec<(ExecutorConfig, &str)> = vec![
            (
                ExecutorConfig {
                    ws: WsConfig { workers: 0, steal_tries: 4 },
                    ..ExecutorConfig::default()
                },
                "workers",
            ),
            (
                ExecutorConfig {
                    ws: WsConfig { workers: MAX_WORKERS + 1, steal_tries: 4 },
                    ..ExecutorConfig::default()
                },
                "workers",
            ),
            (ExecutorConfig { arena_shards: 0, ..ExecutorConfig::default() }, "arena_shards"),
            (
                ExecutorConfig { arena_shards: MAX_ARENA_SHARDS * 2, ..ExecutorConfig::default() },
                "arena_shards",
            ),
            (ExecutorConfig { max_active_jobs: 0, ..ExecutorConfig::default() }, "max_active_jobs"),
            (
                ExecutorConfig { max_inflight_per_job: 0, ..ExecutorConfig::default() },
                "max_inflight_per_job",
            ),
            (
                ExecutorConfig {
                    max_queued_jobs: MAX_QUEUED_JOBS + 1,
                    ..ExecutorConfig::default()
                },
                "max_queued_jobs",
            ),
            (
                ExecutorConfig {
                    default_spec: spec_with(|s| s.deadline = Some(Duration::ZERO)),
                    ..ExecutorConfig::default()
                },
                "deadline",
            ),
            (
                ExecutorConfig {
                    default_spec: spec_with(|s| s.fuel_budget = Some(0)),
                    ..ExecutorConfig::default()
                },
                "fuel_budget",
            ),
            (
                ExecutorConfig {
                    default_spec: spec_with(|s| s.retry.max_attempts = 0),
                    ..ExecutorConfig::default()
                },
                "max_attempts",
            ),
            (
                ExecutorConfig {
                    default_spec: spec_with(|s| s.retry.max_attempts = MAX_RETRY_ATTEMPTS + 1),
                    ..ExecutorConfig::default()
                },
                "max_attempts",
            ),
            (
                ExecutorConfig {
                    default_spec: spec_with(|s| s.retry.backoff = Duration::from_secs(61)),
                    ..ExecutorConfig::default()
                },
                "backoff",
            ),
            (
                ExecutorConfig {
                    fault: Some(FaultPlan { panic_rate: 1.5, ..FaultPlan::disabled() }),
                    ..ExecutorConfig::default()
                },
                "panic_rate",
            ),
            (
                ExecutorConfig {
                    ws: WsConfig { workers: 2, steal_tries: 4 },
                    fault: Some(FaultPlan {
                        kill_worker: Some((2, 1)),
                        ..FaultPlan::disabled()
                    }),
                    ..ExecutorConfig::default()
                },
                "kill_worker",
            ),
        ];
        for (cfg, needle) in cases {
            let err = cfg.validate().expect_err("must be rejected");
            assert!(err.to_string().contains(needle), "{err} should mention {needle}");
            // The same error must surface from construction, before any
            // thread is spawned.
            let err = Executor::new(cfg).expect_err("construction must fail");
            assert!(err.to_string().contains(needle), "{err}");
        }
    }
}
