//! Deterministic fault injection for the resident executor.
//!
//! A [`FaultPlan`] is a *seeded schedule* of faults, not a random one:
//! the faults a job sees are a pure function of `(plan seed, job id,
//! attempt)`, and the dispatch index at which a fault fires is matched
//! against a per-attempt atomic fault clock whose ticks are the job's
//! own dispatch sequence — program-deterministic for non-xla jobs. Two
//! floods of the same corpus under the same seed therefore produce the
//! same per-job [`super::JobError`] outcome, which is what makes chaos
//! testing assertable in CI instead of flaky.
//!
//! Three injection seams (mirroring where real faults bite):
//!
//! - **dispatch** (`Machine::on_dispatch`): panics and transient
//!   failures at an exact dispatch index, plus periodic micro-delays;
//! - **steal** (the worker sourcing loop): timing-only delays, plus the
//!   one-shot [`FaultPlan::kill_worker`] hook that panics a worker
//!   *outside* the task catch — exercising the supervisor respawn path;
//! - **xla flush** (`flush_job_xla`): the same per-job fault clock ticks
//!   once per flushed batch (flush timing is scheduler-dependent, so
//!   outcome determinism is only guaranteed for jobs without xla tasks).
//!
//! Armed via `ExecutorConfig::fault` or the `BOMBYX_CHAOS=<seed>`
//! environment variable (applied by `Executor::new` when the config
//! carries no plan — tests that must stay clean under an ambient chaos
//! env pin `fault: Some(FaultPlan::disabled())`).

use anyhow::{anyhow, bail, Result};

use crate::util::rng::Rng;

/// Environment variable carrying a chaos seed (`u64`).
pub const ENV_CHAOS: &str = "BOMBYX_CHAOS";

/// What an injected fault does when its trigger tick is reached.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum InjectedFault {
    /// `panic!` on the executing worker — exercises `catch_unwind`
    /// containment (or, via `kill_worker`, the supervisor respawn).
    Panic,
    /// Fail the job with a retryable [`super::JobErrorKind::Transient`].
    Transient,
}

/// A fault pinned to one `(job, attempt)` — the test hook for exact
/// containment/retry scenarios. Forced faults bypass the seeded rates.
#[derive(Clone, Copy, Debug)]
pub struct ForcedFault {
    /// Job id (submission order within the executor).
    pub job: u64,
    /// 1-based attempt the fault fires on.
    pub attempt: u32,
    pub kind: InjectedFault,
    /// 1-based fault-clock tick (dispatch index) at which to fire.
    pub at: u64,
}

/// Seeded, deterministic fault schedule. See the module docs.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability that a given `(job, attempt)` gets an injected panic.
    pub panic_rate: f64,
    /// Probability of an injected transient failure (retryable). Rolled
    /// from the same draw as `panic_rate`; the two must sum to <= 1.
    pub transient_rate: f64,
    /// Probability that a `(job, attempt)` gets periodic micro-delays at
    /// dispatch boundaries (timing jitter, never an error).
    pub delay_rate: f64,
    /// Fault triggers are drawn uniformly from `[1, max_trigger]`
    /// fault-clock ticks; jobs that finish earlier outrun their fault.
    pub max_trigger: u64,
    /// First fault-free attempt: attempts `>= fault_free_after` get no
    /// seeded faults, so a retry policy with more attempts than this
    /// always converges (chaos floods stay assertable). `0` disables
    /// the cutoff. Forced faults ignore it.
    pub fault_free_after: u32,
    /// One-shot forced worker death: `(worker id, after N steal
    /// attempts)`. Panics outside the task catch, so the thread dies and
    /// the supervisor must respawn it.
    pub kill_worker: Option<(usize, u64)>,
    /// Exact-scenario overrides checked before the seeded rates.
    pub force: Vec<ForcedFault>,
}

impl FaultPlan {
    /// A plan that injects nothing. Distinct from `config.fault = None`:
    /// an explicit disabled plan also suppresses the `BOMBYX_CHAOS` env
    /// fallback, which is how tests stay deterministic under the CI
    /// chaos-smoke environment.
    pub fn disabled() -> FaultPlan {
        FaultPlan {
            seed: 0,
            panic_rate: 0.0,
            transient_rate: 0.0,
            delay_rate: 0.0,
            max_trigger: 1,
            fault_free_after: 0,
            kill_worker: None,
            force: Vec::new(),
        }
    }

    /// The standard chaos mix used by `--chaos <seed>` and the env
    /// fallback: panics, transients, and delays at moderate rates, with
    /// triggers early enough that small corpus jobs still reach them,
    /// and a fault-free horizon so retries converge.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            panic_rate: 0.10,
            transient_rate: 0.25,
            delay_rate: 0.20,
            max_trigger: 200,
            fault_free_after: 4,
            kill_worker: None,
            force: Vec::new(),
        }
    }

    /// Read `BOMBYX_CHAOS` — `Ok(None)` when unset or empty, a
    /// descriptive error when set but unparseable.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(ENV_CHAOS) {
            Ok(raw) if !raw.trim().is_empty() => {
                let seed = raw.trim().parse::<u64>().map_err(|_| {
                    anyhow!("{ENV_CHAOS}: expected a u64 chaos seed, got `{raw}`")
                })?;
                Ok(Some(FaultPlan::chaos(seed)))
            }
            _ => Ok(None),
        }
    }

    /// Validate before any executor is built; errors name the offending
    /// field like the rest of `ExecutorConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        for (name, rate) in [
            ("fault.panic_rate", self.panic_rate),
            ("fault.transient_rate", self.transient_rate),
            ("fault.delay_rate", self.delay_rate),
        ] {
            if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
                bail!("executor config: {name} must be within [0, 1] (got {rate})");
            }
        }
        if self.panic_rate + self.transient_rate > 1.0 {
            bail!(
                "executor config: fault.panic_rate + fault.transient_rate must be <= 1 (got {})",
                self.panic_rate + self.transient_rate
            );
        }
        if self.max_trigger == 0 {
            bail!("executor config: fault.max_trigger must be >= 1 (got 0)");
        }
        Ok(())
    }

    /// The faults one `(job, attempt)` will see — a pure function of the
    /// plan and its arguments (same inputs, same schedule, every run).
    pub fn for_job(&self, job: u64, attempt: u32) -> JobFaults {
        if let Some(f) = self.force.iter().find(|f| f.job == job && f.attempt == attempt) {
            return JobFaults { fault: Some((f.kind, f.at.max(1))), delay: None };
        }
        let mut rng = Rng::new(
            self.seed
                ^ job.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        let delay = if rng.chance(self.delay_rate) {
            // Sleep 1..=50us every 1..=64 dispatches: enough jitter to
            // shake out scheduling assumptions, cheap enough for floods.
            Some((1 + rng.below(64), 1 + rng.below(50)))
        } else {
            None
        };
        let eligible = self.fault_free_after == 0 || attempt < self.fault_free_after;
        let fault = if eligible {
            let trigger = 1 + rng.below(self.max_trigger);
            let roll = rng.unit_f64();
            if roll < self.panic_rate {
                Some((InjectedFault::Panic, trigger))
            } else if roll < self.panic_rate + self.transient_rate {
                Some((InjectedFault::Transient, trigger))
            } else {
                None
            }
        } else {
            None
        };
        JobFaults { fault, delay }
    }
}

/// The derived per-attempt schedule, stored as atomics in `JobState`.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobFaults {
    /// At most one fault per attempt: `(kind, 1-based trigger tick)`.
    pub fault: Option<(InjectedFault, u64)>,
    /// Periodic micro-delay: `(every N ticks, micros)`.
    pub delay: Option<(u64, u64)>,
}

impl JobFaults {
    pub fn armed(&self) -> bool {
        self.fault.is_some() || self.delay.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_job_is_deterministic() {
        let plan = FaultPlan::chaos(0xC0FFEE);
        for job in 0..64u64 {
            for attempt in 1..=4u32 {
                let a = plan.for_job(job, attempt);
                let b = plan.for_job(job, attempt);
                assert_eq!(format!("{a:?}"), format!("{b:?}"), "job {job} attempt {attempt}");
            }
        }
    }

    #[test]
    fn seeds_produce_different_schedules() {
        // Not a tautology (a constant function would be "deterministic"):
        // across 64 jobs, two seeds must disagree somewhere.
        let a = FaultPlan::chaos(1);
        let b = FaultPlan::chaos(2);
        let differs = (0..64u64)
            .any(|j| format!("{:?}", a.for_job(j, 1)) != format!("{:?}", b.for_job(j, 1)));
        assert!(differs);
    }

    #[test]
    fn chaos_rates_actually_inject() {
        let plan = FaultPlan::chaos(7);
        let armed = (0..256u64).filter(|&j| plan.for_job(j, 1).fault.is_some()).count();
        // panic_rate + transient_rate = 0.35: expect ~90/256; a huge
        // margin guards the assertion, not the exact binomial.
        assert!(armed > 20, "only {armed}/256 attempts armed");
    }

    #[test]
    fn fault_free_horizon_silences_late_attempts() {
        let plan = FaultPlan::chaos(7);
        for job in 0..256u64 {
            assert!(plan.for_job(job, plan.fault_free_after).fault.is_none());
            assert!(plan.for_job(job, plan.fault_free_after + 1).fault.is_none());
        }
    }

    #[test]
    fn forced_faults_override_rates_and_horizon() {
        let mut plan = FaultPlan::disabled();
        plan.force.push(ForcedFault {
            job: 3,
            attempt: 9,
            kind: InjectedFault::Panic,
            at: 17,
        });
        let f = plan.for_job(3, 9).fault.expect("forced fault must arm");
        assert_eq!(f, (InjectedFault::Panic, 17));
        assert!(plan.for_job(3, 1).fault.is_none());
        assert!(plan.for_job(4, 9).fault.is_none());
    }

    #[test]
    fn disabled_plan_injects_nothing() {
        let plan = FaultPlan::disabled();
        for job in 0..64u64 {
            assert!(!plan.for_job(job, 1).armed());
        }
    }

    #[test]
    fn validate_names_offending_fields() {
        let cases: Vec<(FaultPlan, &str)> = vec![
            (FaultPlan { panic_rate: 1.5, ..FaultPlan::disabled() }, "panic_rate"),
            (FaultPlan { transient_rate: -0.1, ..FaultPlan::disabled() }, "transient_rate"),
            (FaultPlan { delay_rate: f64::NAN, ..FaultPlan::disabled() }, "delay_rate"),
            (
                FaultPlan { panic_rate: 0.6, transient_rate: 0.6, ..FaultPlan::disabled() },
                "panic_rate + fault.transient_rate",
            ),
            (FaultPlan { max_trigger: 0, ..FaultPlan::disabled() }, "max_trigger"),
        ];
        for (plan, needle) in cases {
            let err = plan.validate().expect_err("must be rejected");
            assert!(err.to_string().contains(needle), "{err} should mention {needle}");
        }
        assert!(FaultPlan::disabled().validate().is_ok());
        assert!(FaultPlan::chaos(42).validate().is_ok());
    }
}
