//! Multithreaded continuation-passing work-stealing runtime — the Cilk-1
//! "emulation layer" backend of the paper (§II-B), built from scratch.
//!
//! This is what the paper's second compilation target runs on: the explicit
//! IR executed in software to verify the rewrite preserves the original
//! program's semantics. Architecture:
//!
//! - one worker thread per core (configurable), each with its own
//!   lock-free Chase–Lev deque ([`deque`]): the owner pushes/pops the hot
//!   end with no synchronization beyond a fence, thieves CAS the cold
//!   end — no mutex anywhere on the task path;
//! - task bodies are precompiled register bytecode ([`crate::exec`]),
//!   shared with every other engine; a worker's dispatch allocates
//!   nothing (reused frame stack, inline argument lists);
//! - closures live in per-worker arenas with free lists ([`closure`]);
//!   join counters are atomics — a closure fires on the thread that
//!   decrements it to zero;
//! - shared memory ([`shared_mem`]) is word-atomic, matching the FPGA HBM
//!   model (benign races like BFS's visited flags behave as in hardware);
//! - idle thieves back off exponentially (spin, then park with a growing
//!   timeout) instead of hammering victims;
//! - `extern xla` tasks are routed to a batch sink (scalar reference
//!   implementation in tests; the AOT XLA executable in production —
//!   `coordinator::batcher`);
//! - the pool is *resident* ([`executor`]): clients submit jobs — a
//!   kernel program plus a root spawn — against a long-lived
//!   [`Executor`] and join/cancel them through [`JobHandle`]s; the
//!   one-shot [`run`] / [`run_with_kernels`] entry points below are thin
//!   wrappers that submit a single job and tear the pool down.

pub mod closure;
pub mod deque;
pub mod error;
pub mod executor;
pub mod fault;
pub mod shared_mem;
pub mod worker;

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use anyhow::{anyhow, Result};

use crate::exec::{KernelMode, KernelProgram};
use crate::ir::cfg::Module;
use crate::ir::expr::Value;

pub use closure::{Cont, Registry, StaleHandle};
pub use error::{JobError, JobErrorKind, Trap};
pub use executor::{
    Executor, ExecutorConfig, ExecutorStats, Job, JobHandle, JobId, JobSpec, RetryPolicy,
};
pub use fault::{FaultPlan, ForcedFault, InjectedFault};
pub use shared_mem::SharedMemory;

/// Poison-tolerant mutex lock, used for every mutex in this runtime.
/// With task panics caught and contained ([`worker`]), a poisoned mutex
/// only means "a panic unwound while holding this lock"; all ws lock
/// scopes leave their data consistent at every await-free step (pushes
/// complete, counters are atomics), so propagating the poison would turn
/// one contained fault into a pool-wide cascade for no soundness gain.
pub(crate) fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Batch execution sink for `extern xla` tasks.
pub trait XlaSink: Send + Sync {
    /// Execute a batch of task instances of task `name`; one result per
    /// instance, in order.
    fn exec_batch(
        &self,
        name: &str,
        batch: &[Vec<Value>],
        mem: &SharedMemory,
    ) -> Result<Vec<Value>>;

    /// Preferred batch size (the runtime flushes at this size or when idle).
    fn preferred_batch(&self) -> usize {
        64
    }
}

/// Rejects xla tasks (programs without `extern xla`).
pub struct NoXlaSink;

impl XlaSink for NoXlaSink {
    fn exec_batch(&self, name: &str, _b: &[Vec<Value>], _m: &SharedMemory) -> Result<Vec<Value>> {
        Err(anyhow!("xla task `{name}` spawned but no XLA sink configured"))
    }
}

/// Scalar per-instance sink adapter (reference mode).
pub struct ScalarSink<F>(pub F)
where
    F: Fn(&str, &[Value], &SharedMemory) -> Result<Value> + Send + Sync;

impl<F> XlaSink for ScalarSink<F>
where
    F: Fn(&str, &[Value], &SharedMemory) -> Result<Value> + Send + Sync,
{
    fn exec_batch(&self, name: &str, batch: &[Vec<Value>], mem: &SharedMemory) -> Result<Vec<Value>> {
        batch.iter().map(|args| (self.0)(name, args, mem)).collect()
    }
}

#[derive(Clone, Debug)]
pub struct WsConfig {
    pub workers: usize,
    /// Steal attempts before a worker backs off.
    pub steal_tries: usize,
}

impl Default for WsConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
        WsConfig { workers, steal_tries: 4 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct WsStats {
    pub tasks_run: u64,
    pub steals: u64,
    pub closures_made: u64,
    /// High-water mark of simultaneously live closures (registry peak).
    pub max_live_closures: u64,
    pub xla_batches: u64,
    pub xla_tasks: u64,
    /// Kernel instructions retired across all workers (a fused
    /// superinstruction retires as one dispatch).
    pub instrs: u64,
}

/// Run a task program on the WS runtime; returns the root result, final
/// memory and stats. Compiles the kernel program on entry — use
/// [`run_with_kernels`] (or the session API) to reuse a cached one.
pub fn run(
    module: &Module,
    memory: SharedMemory,
    name: &str,
    args: &[Value],
    config: &WsConfig,
    xla_sink: Box<dyn XlaSink>,
) -> Result<(Value, SharedMemory, WsStats)> {
    let kernels = Arc::new(crate::exec::compile_module(module, KernelMode::Explicit)?);
    run_with_kernels(kernels, memory, name, args, config, xla_sink)
}

/// [`run`] over an already-compiled kernel program (the single source of
/// truth for task metadata — no module handle to drift out of sync).
///
/// Thin wrapper over the resident [`Executor`]: construct a pool of
/// `config.workers`, submit the one job, join it, tear the pool down.
/// Multi-job clients should hold an [`Executor`] directly.
pub fn run_with_kernels(
    kernels: Arc<KernelProgram>,
    memory: SharedMemory,
    name: &str,
    args: &[Value],
    config: &WsConfig,
    xla_sink: Box<dyn XlaSink>,
) -> Result<(Value, SharedMemory, WsStats)> {
    let exec_config = ExecutorConfig {
        ws: WsConfig { workers: config.workers.max(1), steal_tries: config.steal_tries },
        ..ExecutorConfig::default()
    };
    let exec = Executor::new(exec_config)?;
    let handle = exec.submit(Job {
        kernels,
        memory,
        entry: name.to_string(),
        args: args.to_vec(),
        xla_sink,
        spec: JobSpec::default(),
    })?;
    let (value, memory, stats) = handle.join()?;
    // Joining the workers releases every transient reference to the
    // job's memory image, so unwrapping the Arc back to the by-value
    // signature is deterministic.
    drop(exec);
    let memory = Arc::try_unwrap(memory)
        .unwrap_or_else(|_| unreachable!("executor dropped, memory has a sole owner"));
    Ok((value, memory, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::{compile, CompileOptions};

    fn ws_run(src: &str, name: &str, args: &[i64], workers: usize) -> (i64, WsStats) {
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let mem = SharedMemory::new(&r.explicit);
        let vals: Vec<Value> = args.iter().map(|&a| Value::I64(a)).collect();
        let cfg = WsConfig { workers, steal_tries: 4 };
        let (v, _, stats) = run(&r.explicit, mem, name, &vals, &cfg, Box::new(NoXlaSink)).unwrap();
        (v.as_i64(), stats)
    }

    const FIB: &str = "int fib(int n) {
        if (n < 2) return n;
        int x = cilk_spawn fib(n - 1);
        int y = cilk_spawn fib(n - 2);
        cilk_sync;
        return x + y;
    }";

    #[test]
    fn fib_single_worker() {
        assert_eq!(ws_run(FIB, "fib", &[15], 1).0, 610);
    }

    #[test]
    fn fib_parallel_matches() {
        for workers in [2, 4, 8] {
            let (v, stats) = ws_run(FIB, "fib", &[18], workers);
            assert_eq!(v, 2584, "workers={workers}");
            assert!(stats.tasks_run > 1000);
            assert!(stats.max_live_closures > 0);
        }
    }

    #[test]
    fn parallel_is_deterministic_for_deterministic_programs() {
        for _ in 0..5 {
            let (v, _) = ws_run(FIB, "fib", &[16], 8);
            assert_eq!(v, 987);
        }
    }

    #[test]
    fn bfs_parallel_visits_everything() {
        let src = "global int adj_off[];
            global int adj_edges[];
            global int visited[];
            void visit(int n) {
                int off = adj_off[n];
                int end = adj_off[n + 1];
                visited[n] = 1;
                for (int i = off; i < end; i = i + 1) {
                    cilk_spawn visit(adj_edges[i]);
                }
                cilk_sync;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        // Full binary tree with 7 nodes in CSR.
        let mut mem = SharedMemory::new(m);
        mem.fill_i64(m.global_by_name("adj_off").unwrap(), &[0, 2, 4, 6, 6, 6, 6, 6]);
        mem.fill_i64(m.global_by_name("adj_edges").unwrap(), &[1, 2, 3, 4, 5, 6]);
        mem.resize(m.global_by_name("visited").unwrap(), 7);
        let cfg = WsConfig { workers: 4, steal_tries: 4 };
        let (v, mem, _) =
            run(m, mem, "visit", &[Value::I64(0)], &cfg, Box::new(NoXlaSink)).unwrap();
        assert_eq!(v, Value::Unit);
        assert_eq!(mem.dump_i64(m.global_by_name("visited").unwrap()), vec![1; 7]);
    }

    #[test]
    fn atomic_add_under_contention() {
        let src = "global int acc[1];
            void bump(int n) { atomic_add(acc, 0, 1); }
            void f(int n) {
                for (int i = 0; i < n; i = i + 1) {
                    cilk_spawn bump(i);
                }
                cilk_sync;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mem = SharedMemory::new(m);
        let cfg = WsConfig { workers: 8, steal_tries: 4 };
        let (_, mem, _) = run(m, mem, "f", &[Value::I64(5000)], &cfg, Box::new(NoXlaSink)).unwrap();
        assert_eq!(mem.dump_i64(m.global_by_name("acc").unwrap()), vec![5000]);
    }

    #[test]
    fn error_in_task_propagates() {
        // Out-of-bounds store must surface as Err, not deadlock.
        let src = "global int a[2];
            void f(int n) { a[100] = 1; }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mem = SharedMemory::new(m);
        let cfg = WsConfig { workers: 4, steal_tries: 4 };
        let err = run(m, mem, "f", &[Value::I64(0)], &cfg, Box::new(NoXlaSink)).unwrap_err();
        assert!(err.to_string().contains("out-of-bounds"), "{err}");
    }

    #[test]
    fn xla_tasks_are_batched() {
        let src = "extern xla int double_(int n);
            global int out[];
            void f(int n) {
                for (int i = 0; i < n; i = i + 1) {
                    cilk_spawn put(i);
                }
                cilk_sync;
            }
            void put(int i) {
                int d = cilk_spawn double_(i);
                cilk_sync;
                out[i] = d;
            }";
        let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
        let m = &r.explicit;
        let mut mem = SharedMemory::new(m);
        mem.resize(m.global_by_name("out").unwrap(), 100);
        let sink = ScalarSink(|_name: &str, args: &[Value], _mem: &SharedMemory| {
            Ok(Value::I64(args[0].as_i64() * 2))
        });
        let cfg = WsConfig { workers: 4, steal_tries: 4 };
        let (_, mem, stats) =
            run(m, mem, "f", &[Value::I64(100)], &cfg, Box::new(sink)).unwrap();
        let out = mem.dump_i64(m.global_by_name("out").unwrap());
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<i64>>());
        assert_eq!(stats.xla_tasks, 100);
        assert!(
            stats.xla_batches <= 100,
            "batches bounded by tasks: {} batches",
            stats.xla_batches
        );
    }
}
