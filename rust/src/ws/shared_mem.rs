//! Word-atomic shared memory for the multithreaded runtime.
//!
//! Values are stored as 64-bit patterns in `AtomicU64` cells. Plain loads
//! and stores are `Relaxed` single-word atomics — the same guarantee an
//! HBM channel gives concurrent PEs on the FPGA (no tearing, no ordering).
//! `atomic_add` is a CAS loop (int: fetch-add semantics; float: CAS on the
//! bit pattern).

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use crate::frontend::ast::Type;
use crate::ir::cfg::{GlobalId, Module};
use crate::ir::expr::Value;

pub struct SharedMemory {
    arrays: Vec<Vec<AtomicU64>>,
    elems: Vec<Type>,
}

impl std::fmt::Debug for SharedMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedMemory({} arrays)", self.arrays.len())
    }
}

impl SharedMemory {
    pub fn new(module: &Module) -> SharedMemory {
        let mut arrays = Vec::new();
        let mut elems = Vec::new();
        for (_, g) in module.globals.iter() {
            let len = g.size.unwrap_or(0) as usize;
            arrays.push((0..len).map(|_| AtomicU64::new(zero_bits(g.elem))).collect());
            elems.push(g.elem);
        }
        SharedMemory { arrays, elems }
    }

    /// Build from a sequential [`crate::interp::Memory`]-style snapshot.
    pub fn from_values(module: &Module, values: Vec<Vec<Value>>) -> SharedMemory {
        let mut mem = SharedMemory::new(module);
        for (gi, col) in values.into_iter().enumerate() {
            mem.arrays[gi] = col.into_iter().map(|v| AtomicU64::new(v.to_bits())).collect();
        }
        mem
    }

    pub fn resize(&mut self, id: GlobalId, len: usize) {
        let z = zero_bits(self.elems[id.index()]);
        let arr = &mut self.arrays[id.index()];
        while arr.len() < len {
            arr.push(AtomicU64::new(z));
        }
        arr.truncate(len);
    }

    pub fn len(&self, id: GlobalId) -> usize {
        self.arrays[id.index()].len()
    }

    pub fn is_empty(&self, id: GlobalId) -> bool {
        self.arrays[id.index()].is_empty()
    }

    pub fn elem(&self, id: GlobalId) -> Type {
        self.elems[id.index()]
    }

    #[inline]
    pub fn load(&self, id: GlobalId, index: i64) -> Result<Value> {
        let cell = self.arrays[id.index()].get(index as usize).ok_or_else(|| {
            anyhow!(
                "out-of-bounds load: global #{} index {} (len {})",
                id.index(),
                index,
                self.arrays[id.index()].len()
            )
        })?;
        Ok(Value::from_bits(self.elems[id.index()], cell.load(Ordering::Relaxed)))
    }

    #[inline]
    pub fn store(&self, id: GlobalId, index: i64, value: Value) -> Result<()> {
        let elem = self.elems[id.index()];
        let len = self.arrays[id.index()].len();
        let cell = self.arrays[id.index()].get(index as usize).ok_or_else(|| {
            anyhow!("out-of-bounds store: global #{} index {} (len {})", id.index(), index, len)
        })?;
        cell.store(value.coerce(elem).to_bits(), Ordering::Relaxed);
        Ok(())
    }

    #[inline]
    pub fn atomic_add(&self, id: GlobalId, index: i64, value: Value) -> Result<()> {
        let elem = self.elems[id.index()];
        let len = self.arrays[id.index()].len();
        let cell = self.arrays[id.index()].get(index as usize).ok_or_else(|| {
            anyhow!(
                "out-of-bounds atomic_add: global #{} index {} (len {})",
                id.index(),
                index,
                len
            )
        })?;
        match elem {
            Type::Float => {
                let add = value.as_f32();
                let mut cur = cell.load(Ordering::Relaxed);
                loop {
                    let new = Value::F32(f32::from_bits(cur as u32) + add).to_bits();
                    match cell.compare_exchange_weak(
                        cur,
                        new,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(actual) => cur = actual,
                    }
                }
            }
            _ => {
                cell.fetch_add(value.as_i64() as u64, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    pub fn fill_i64(&mut self, id: GlobalId, values: &[i64]) {
        let elem = self.elems[id.index()];
        self.arrays[id.index()] = values
            .iter()
            .map(|&v| AtomicU64::new(Value::I64(v).coerce(elem).to_bits()))
            .collect();
    }

    pub fn fill_f32(&mut self, id: GlobalId, values: &[f32]) {
        let elem = self.elems[id.index()];
        self.arrays[id.index()] = values
            .iter()
            .map(|&v| AtomicU64::new(Value::F32(v).coerce(elem).to_bits()))
            .collect();
    }

    pub fn dump_i64(&self, id: GlobalId) -> Vec<i64> {
        let elem = self.elems[id.index()];
        self.arrays[id.index()]
            .iter()
            .map(|c| Value::from_bits(elem, c.load(Ordering::Relaxed)).as_i64())
            .collect()
    }

    pub fn dump_f32(&self, id: GlobalId) -> Vec<f32> {
        let elem = self.elems[id.index()];
        self.arrays[id.index()]
            .iter()
            .map(|c| Value::from_bits(elem, c.load(Ordering::Relaxed)).as_f32())
            .collect()
    }
}

fn zero_bits(ty: Type) -> u64 {
    Value::zero_of(ty).to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::cfg::Global;

    fn mem(elem: Type, size: u64) -> SharedMemory {
        let mut m = Module::default();
        m.globals.push(Global { name: "a".into(), elem, size: Some(size) });
        SharedMemory::new(&m)
    }

    #[test]
    fn atomic_add_is_atomic_across_threads() {
        let m = mem(Type::Int, 1);
        let g = GlobalId::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        m.atomic_add(g, 0, Value::I64(1)).unwrap();
                    }
                });
            }
        });
        assert_eq!(m.dump_i64(g), vec![80_000]);
    }

    #[test]
    fn float_atomic_add() {
        let m = mem(Type::Float, 1);
        let g = GlobalId::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        m.atomic_add(g, 0, Value::F32(1.0)).unwrap();
                    }
                });
            }
        });
        assert_eq!(m.dump_f32(g), vec![4000.0]);
    }

    #[test]
    fn oob_reports_error() {
        let m = mem(Type::Int, 2);
        let g = GlobalId::new(0);
        assert!(m.load(g, 5).is_err());
        assert!(m.store(g, -1, Value::I64(0)).is_err());
    }
}
