//! Worker loop and kernel-machine task execution for the WS runtime.
//!
//! Each worker owns a lock-free Chase–Lev deque ([`super::deque`]): its
//! own pushes/pops touch no lock, thieves CAS the cold end. Task bodies
//! run on the shared compiled kernels ([`crate::exec`]) through
//! [`WsMachine`], whose side effects are the owning job's concurrent
//! closure registry and word-atomic shared memory.
//!
//! Workers are *resident* ([`super::executor`]): they interleave tasks
//! from every active job. A [`WsTask`] carries its `Arc<JobState>`, so a
//! steal moves the whole job context with the task and the deques stay
//! job-oblivious. The sourcing order is (1) a periodic poll of the
//! round-robin injector — fairness: a hot local deque cannot starve a
//! freshly admitted job's root — then (2) the own deque, (3) the
//! injector, (4) stealing, (5) the xla batch queues, then exponential
//! backoff (spin first, then park on the idle condvar with a growing
//! timeout) so contended steals never spin hot and the push path pays a
//! futex only when somebody actually sleeps.
//!
//! Cancellation is cooperative: a cancelled job's queued tasks are
//! discarded at pop, and running tasks abort at the next dispatch
//! boundary via the [`Machine::on_dispatch`] hook.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::exec::{run_kernel, ArgList, KStack, KontRef, Machine};
use crate::ir::cfg::{FuncId, FuncKind, GlobalId};
use crate::ir::expr::Value;

use crate::obs::{self, trace::ArgVal};

use super::closure::{Cont, SharedClosure};
use super::executor::{fail_job, finish_one, ExecShared, JobState};

/// A runnable task instance, tagged with its owning job.
#[derive(Clone)]
pub struct WsTask {
    pub(crate) job: Arc<JobState>,
    pub task: FuncId,
    pub args: ArgList,
    pub cont: Cont,
}

impl std::fmt::Debug for WsTask {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WsTask")
            .field("job", &self.job.id)
            .field("task", &self.task)
            .field("cont", &self.cont)
            .finish()
    }
}

/// Spin rounds before a thief starts parking.
const SPIN_ROUNDS: u32 = 6;
/// Cap on the parking-backoff exponent (50us << 2 = 200us max — the
/// notify race between a push's `idle_workers` check and a parker's
/// increment is bounded by the timeout, so the cap keeps the worst-case
/// lost-wakeup latency at the pre-rework 200us bound).
const MAX_PARK_SHIFT: u32 = 2;
/// Local tasks executed between injector polls. Prime, so the poll
/// cadence does not phase-lock with power-of-two task-tree shapes.
const INJECT_PERIOD: u32 = 61;

pub(crate) fn worker_loop(wid: usize, shared: &ExecShared) {
    if obs::trace_enabled() {
        obs::trace::set_thread_name(&format!("ws-worker-{wid}"));
    }
    let nworkers = shared.deques.len();
    let steal_tries = shared.config.ws.steal_tries.max(1);
    let mut rng = crate::util::rng::Rng::new(0x5EED ^ wid as u64);
    // Per-worker kernel frame stack, reused across tasks and jobs: task
    // dispatch allocates nothing on the hot path.
    let mut stack = KStack::new();
    let mut backoff: u32 = 0;
    let mut since_inject: u32 = 0;
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // 0. Fairness: service the round-robin injector periodically even
        // while the local deque is hot, so a resident job's task flood
        // cannot starve a freshly admitted root or overflow lane.
        if since_inject >= INJECT_PERIOD {
            since_inject = 0;
            obs::metrics::counter_add("ws.injector_polls", 1);
            if obs::trace_enabled() {
                obs::trace::instant("injector-poll", "ws", Vec::new());
            }
            if let Some(task) = shared.pop_injected() {
                backoff = 0;
                execute(wid, shared, task, &mut stack);
                continue;
            }
        }
        // 1. Own deque (LIFO hot end, lock-free owner path).
        if let Some(task) = shared.deques[wid].pop() {
            backoff = 0;
            since_inject += 1;
            execute(wid, shared, task, &mut stack);
            continue;
        }
        // 2. Injector lanes (new job roots, per-job spawn overflow).
        if let Some(task) = shared.pop_injected() {
            backoff = 0;
            since_inject = 0;
            execute(wid, shared, task, &mut stack);
            continue;
        }
        // 3. Steal (FIFO cold end of random victims, CAS only). The
        // in_steal flag brackets the window in which this thief may hold
        // a victim's buffer pointer — the executor's quiescent
        // reclamation of retired buffers keys off it.
        if nworkers > 1 {
            shared.in_steal[wid].store(true, Ordering::SeqCst);
            let mut stolen = None;
            for _ in 0..steal_tries {
                let victim = rng.below(nworkers as u64) as usize;
                if victim == wid {
                    continue;
                }
                if let Some(t) = shared.deques[victim].steal() {
                    stolen = Some(t);
                    break;
                }
            }
            shared.in_steal[wid].store(false, Ordering::SeqCst);
            if let Some(task) = stolen {
                backoff = 0;
                since_inject += 1;
                task.job.counters.steals.fetch_add(1, Ordering::Relaxed);
                if obs::trace_enabled() {
                    obs::trace::instant(
                        "steal",
                        "ws",
                        vec![("job", ArgVal::I64(task.job.id.0 as i64))],
                    );
                }
                execute(wid, shared, task, &mut stack);
                continue;
            }
        }
        // 4. Flush pending xla batch work across active jobs.
        if flush_xla(wid, shared) {
            backoff = 0;
            continue;
        }
        // 5. Exponential backoff: spin a few rounds, then park with a
        // growing timeout (pushers notify; the idle counter gates the
        // futex syscall on the push path).
        if backoff < SPIN_ROUNDS {
            for _ in 0..(8u32 << backoff) {
                std::hint::spin_loop();
            }
            backoff += 1;
            continue;
        }
        let park_us = 50u64 << (backoff - SPIN_ROUNDS).min(MAX_PARK_SHIFT);
        obs::metrics::counter_add("ws.parks", 1);
        if obs::trace_enabled() {
            obs::trace::instant("park", "ws", vec![("us", ArgVal::I64(park_us as i64))]);
        }
        backoff = backoff.saturating_add(1);
        shared.idle_workers.fetch_add(1, Ordering::SeqCst);
        let guard = shared.idle_lock.lock().unwrap();
        let _ = shared
            .idle_cv
            .wait_timeout(guard, Duration::from_micros(park_us))
            .unwrap();
        shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Flush queued xla instances through each active job's batch sink.
/// Returns true if any work was done.
fn flush_xla(wid: usize, shared: &ExecShared) -> bool {
    if shared.xla_pending.load(Ordering::SeqCst) == 0 {
        return false;
    }
    let mut did = false;
    for job in shared.active_jobs() {
        did |= flush_job_xla(wid, shared, &job);
    }
    did
}

/// Drain one job's xla queue through its batch sink. Arguments and
/// continuations are *moved* out of the queued instances — the queue
/// already holds the owned `Vec<Value>` rows the sink consumes (staged
/// at spawn from the kernel's arg-staging slots), so the flush performs
/// no per-instance `ArgList` conversion; task names are borrowed from
/// the kernels.
///
/// Accounting contract: every drained instance is `finish_one`d exactly
/// once, whether it was delivered, skipped on cancellation, or orphaned
/// by a sink error — per-job completion counters tolerate no leaks.
fn flush_job_xla(wid: usize, shared: &ExecShared, job: &Arc<JobState>) -> bool {
    let mut batch: Vec<(FuncId, Vec<Value>, Cont)> = {
        let mut q = job.xla_queue.lock().unwrap();
        if q.is_empty() {
            return false;
        }
        let take = q.len().min(job.xla_sink.preferred_batch());
        q.drain(..take).collect()
    };
    let drained = batch.len();
    shared.xla_pending.fetch_sub(drained as u64, Ordering::SeqCst);
    if !job.is_cancelled() {
        // Group by task id, preserving order within each group.
        let mut groups: Vec<(FuncId, Vec<usize>)> = Vec::new();
        for (i, (fid, _, _)) in batch.iter().enumerate() {
            match groups.iter_mut().find(|(g, _)| g == fid) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((*fid, vec![i])),
            }
        }
        'groups: for (fid, idxs) in groups {
            let name = &job.kernels.kernel(fid).name;
            let args: Vec<Vec<Value>> = idxs
                .iter()
                .map(|&i| std::mem::take(&mut batch[i].1))
                .collect();
            job.counters.xla_batches.fetch_add(1, Ordering::Relaxed);
            job.counters.xla_tasks.fetch_add(idxs.len() as u64, Ordering::Relaxed);
            match job.xla_sink.exec_batch(name, &args, &job.memory) {
                Ok(results) => {
                    if results.len() != idxs.len() {
                        fail_job(
                            shared,
                            job,
                            anyhow!(
                                "xla sink returned {} results for {} instances of `{name}`",
                                results.len(),
                                idxs.len()
                            ),
                        );
                        break 'groups;
                    }
                    for (&i, value) in idxs.iter().zip(results) {
                        let cont = std::mem::replace(&mut batch[i].2, Cont::Root);
                        if let Err(e) = deliver(wid, shared, job, cont, value) {
                            fail_job(shared, job, e);
                            break 'groups;
                        }
                    }
                }
                Err(e) => {
                    fail_job(shared, job, e);
                    break 'groups;
                }
            }
        }
    }
    drop(batch);
    for _ in 0..drained {
        finish_one(shared, job);
    }
    true
}

fn execute(wid: usize, shared: &ExecShared, task: WsTask, stack: &mut KStack) {
    let job = Arc::clone(&task.job);
    if job.is_cancelled() {
        // Discard without running; the task's continuation (and any
        // closures it holds) drops here, the arena sweep at completion
        // reclaims the rest.
        obs::metrics::counter_add("ws.cancel_sweeps", 1);
        if obs::trace_enabled() {
            obs::trace::instant(
                "cancel-sweep",
                "ws",
                vec![("job", ArgVal::I64(job.id.0 as i64))],
            );
        }
        drop(task);
        finish_one(shared, &job);
        return;
    }
    job.counters.tasks_run.fetch_add(1, Ordering::Relaxed);
    // The per-task dispatch span: a `B`/`E` pair on this worker's tid,
    // tagged with the owning job so job async spans nest their children.
    let span_name: Option<String> = if obs::trace_enabled() {
        if !job.first_dispatched.swap(true, Ordering::Relaxed) {
            obs::trace::async_instant("first-dispatch", "job", job.id.0, Vec::new());
        }
        let name = job.kernels.kernel(task.task).name.clone();
        obs::trace::begin_args(
            name.clone(),
            "task",
            vec![("job", ArgVal::I64(job.id.0 as i64))],
        );
        Some(name)
    } else {
        None
    };
    let retired_before = stack.retired();
    let outcome = run_task(wid, shared, &job, task, stack);
    job.counters.instrs.fetch_add(stack.retired() - retired_before, Ordering::Relaxed);
    if let Some(name) = span_name {
        obs::trace::end(name, "task");
    }
    if let Err(e) = outcome {
        // A cancelled task's dispatch-boundary bail is expected noise;
        // anything else is the job's first real error (counted failed at
        // fail time, not at graph drain).
        if !job.is_cancelled() {
            fail_job(shared, &job, e);
        }
    }
    finish_one(shared, &job);
}

/// Push a new runnable task (pending already incremented by caller).
/// Within budget it lands on this worker's own deque; a job past its
/// in-flight budget overflows into its round-robin injector lane so it
/// cannot monopolize the pool.
fn push_task(wid: usize, shared: &ExecShared, task: WsTask) {
    if task.job.pending.load(Ordering::Relaxed) > shared.config.max_inflight_per_job as u64 {
        shared.inject(task);
        return;
    }
    shared.deques[wid].push(task);
    shared.notify_if_idle();
}

fn deliver(
    wid: usize,
    shared: &ExecShared,
    job: &Arc<JobState>,
    cont: Cont,
    value: Value,
) -> Result<()> {
    match cont {
        Cont::Root => {
            let mut slot = job.result.lock().unwrap();
            if slot.is_some() {
                bail!("root continuation received two results");
            }
            *slot = Some(value);
        }
        Cont::Slot { clos, slot } => {
            clos.fill(slot, value);
            if clos.release() {
                fire(wid, shared, job, &clos);
            }
        }
        Cont::Counter { clos } => {
            if clos.release() {
                fire(wid, shared, job, &clos);
            }
        }
    }
    Ok(())
}

fn fire(wid: usize, shared: &ExecShared, job: &Arc<JobState>, clos: &Arc<SharedClosure>) {
    let handle = clos.handle.load(Ordering::Relaxed);
    if handle >= 0 {
        job.registry.remove(handle);
    }
    let task = WsTask {
        job: Arc::clone(job),
        task: clos.task,
        args: clos.take_args(),
        cont: clos.take_cont(),
    };
    job.pending.fetch_add(1, Ordering::AcqRel);
    push_task(wid, shared, task);
}

/// The worker's [`Machine`]: per-job closure registry + shared memory
/// effects, plus the cooperative-cancellation dispatch check.
struct WsMachine<'a> {
    wid: usize,
    shared: &'a ExecShared,
    job: &'a Arc<JobState>,
    cont: Cont,
}

fn run_task(
    wid: usize,
    shared: &ExecShared,
    job: &Arc<JobState>,
    inst: WsTask,
    stack: &mut KStack,
) -> Result<()> {
    let kernel = job.kernels.kernel(inst.task);

    if kernel.kind == FuncKind::Xla {
        // Shouldn't reach a deque (spawns route xla tasks to the batch
        // queue) — but a root xla task arrives here; run it as a batch of 1.
        let out = job
            .xla_sink
            .exec_batch(&kernel.name, &[inst.args.into_vec()], &job.memory)?
            .pop()
            .ok_or_else(|| anyhow!("empty xla result"))?;
        return deliver(wid, shared, job, inst.cont, out);
    }

    let mut machine = WsMachine { wid, shared, job, cont: inst.cont };
    let value = run_kernel(
        &job.kernels,
        inst.task,
        inst.args.as_slice(),
        stack,
        &mut machine,
        100_000_000,
    )?;
    if kernel.kind == FuncKind::Leaf {
        // A spawned leaf: its sequential return value is the send.
        let cont = machine.cont;
        return deliver(wid, shared, job, cont, value);
    }
    Ok(())
}

impl<'a> Machine for WsMachine<'a> {
    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
        self.job.memory.load(arr, index)
    }

    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.job.memory.store(arr, index, value)
    }

    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.job.memory.atomic_add(arr, index, value)
    }

    fn on_dispatch(&mut self, fid: FuncId, _depth: usize) -> Result<()> {
        // The cooperative-cancellation boundary: one relaxed load per
        // frame entry, so a cancelled job's running tasks unwind at the
        // next dispatch instead of draining their whole subtree.
        if self.job.is_cancelled() {
            bail!("{} cancelled at dispatch boundary", self.job.id);
        }
        // Hotness profile: once per frame entry (never per retired
        // instruction), behind one relaxed load when disabled.
        if obs::profile_enabled() {
            obs::profile::hit(&self.job.kernels.kernel(fid).name);
        }
        Ok(())
    }

    fn make_closure(&mut self, task: FuncId) -> Result<Value> {
        self.job.counters.closures_made.fetch_add(1, Ordering::Relaxed);
        let slot_tys = Arc::clone(&self.job.kernels.kernel(task).param_tys);
        let clos = Arc::new(SharedClosure::new(task, slot_tys, self.cont.clone()));
        let handle = self.job.registry.insert(clos.clone(), self.wid);
        clos.handle.store(handle, Ordering::Relaxed);
        Ok(Value::I64(handle))
    }

    fn closure_store(&mut self, clos: Value, field: u32, value: Value) -> Result<()> {
        self.job.registry.get(clos.as_i64()).fill(field, value);
        Ok(())
    }

    fn spawn_child(&mut self, callee: FuncId, args: &[Value], ret: KontRef) -> Result<()> {
        let cont = match ret {
            KontRef::Slot { clos, field } => {
                let c = self.job.registry.get(clos.as_i64());
                c.hold();
                Cont::Slot { clos: c, slot: field }
            }
            KontRef::Counter { clos } => {
                let c = self.job.registry.get(clos.as_i64());
                c.hold();
                Cont::Counter { clos: c }
            }
            KontRef::Forward => self.cont.clone(),
        };
        self.job.pending.fetch_add(1, Ordering::AcqRel);
        if self.job.kernels.kernel(callee).kind == FuncKind::Xla {
            // `args` is the spawner's kernel arg-staging slot slice: copy
            // it straight into the owned row the batch sink will consume
            // (no ArgList intermediary to convert at flush time). The row
            // is built before taking the queue lock so the allocation
            // never sits inside the shared critical section.
            let row = args.to_vec();
            self.job.xla_queue.lock().unwrap().push((callee, row, cont));
            self.shared.xla_pending.fetch_add(1, Ordering::SeqCst);
            // Same idle gate as push_task: pay the futex only when a
            // worker actually sleeps.
            self.shared.notify_if_idle();
        } else {
            push_task(
                self.wid,
                self.shared,
                WsTask {
                    job: Arc::clone(self.job),
                    task: callee,
                    args: ArgList::from_slice(args),
                    cont,
                },
            );
        }
        Ok(())
    }

    fn close_spawns(&mut self, clos: Value) -> Result<()> {
        let c = self.job.registry.get(clos.as_i64());
        if c.release() {
            fire(self.wid, self.shared, self.job, &c);
        }
        Ok(())
    }

    fn send_argument(&mut self, value: Value) -> Result<()> {
        deliver(self.wid, self.shared, self.job, self.cont.clone(), value)
    }
}
