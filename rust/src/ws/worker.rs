//! Worker loop and kernel-machine task execution for the WS runtime.
//!
//! Each worker owns a lock-free Chase–Lev deque ([`super::deque`]): its
//! own pushes/pops touch no lock, thieves CAS the cold end. Task bodies
//! run on the shared compiled kernels ([`crate::exec`]) through
//! [`WsMachine`], whose side effects are the owning job's concurrent
//! closure registry and word-atomic shared memory.
//!
//! Workers are *resident* ([`super::executor`]): they interleave tasks
//! from every active job. A [`WsTask`] carries its `Arc<JobState>`, so a
//! steal moves the whole job context with the task and the deques stay
//! job-oblivious. The sourcing order is (1) a periodic poll of the
//! round-robin injector — fairness: a hot local deque cannot starve a
//! freshly admitted job's root — then (2) the own deque, (3) the
//! injector, (4) stealing, (5) the xla batch queues, then exponential
//! backoff (spin first, then park on the idle condvar with a growing
//! timeout) so contended steals never spin hot and the push path pays a
//! futex only when somebody actually sleeps.
//!
//! **Fault containment.** Every task body (and every xla batch flush)
//! runs inside `std::panic::catch_unwind`: a panic — a kernel bug, a
//! sink bug, or an injected chaos panic — fails the owning *job* with a
//! structured [`JobError::panicked`] and the worker keeps serving every
//! other job. A panic that escapes the catch anyway (e.g. the injected
//! worker-kill in the sourcing loop) trips [`DeathWatch`], which
//! registers the worker id for the supervisor to respawn — the pool
//! never silently shrinks.
//!
//! Cancellation is cooperative: an aborted job's queued tasks are
//! discarded at pop, and running tasks abort at the next dispatch
//! boundary via the [`Machine::on_dispatch`] hook — the same metered
//! seam that enforces [`super::JobSpec`] deadlines and fuel budgets and
//! fires the deterministic fault plan.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::exec::{run_kernel, ArgList, KStack, KontRef, Machine};
use crate::ir::cfg::{FuncId, FuncKind, GlobalId};
use crate::ir::expr::Value;

use crate::obs::{self, trace::ArgVal};

use super::closure::{Cont, SharedClosure};
use super::error::JobError;
use super::executor::{fail_job, finish_one, ExecShared, JobState};
use super::fault::InjectedFault;
use super::plock;

/// A runnable task instance, tagged with its owning job.
#[derive(Clone)]
pub struct WsTask {
    pub(crate) job: Arc<JobState>,
    pub task: FuncId,
    pub args: ArgList,
    pub cont: Cont,
}

impl std::fmt::Debug for WsTask {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WsTask")
            .field("job", &self.job.id)
            .field("task", &self.task)
            .field("cont", &self.cont)
            .finish()
    }
}

/// Spin rounds before a thief starts parking.
const SPIN_ROUNDS: u32 = 6;
/// Cap on the parking-backoff exponent (50us << 2 = 200us max — the
/// notify race between a push's `idle_workers` check and a parker's
/// increment is bounded by the timeout, so the cap keeps the worst-case
/// lost-wakeup latency at the pre-rework 200us bound).
const MAX_PARK_SHIFT: u32 = 2;
/// Local tasks executed between injector polls. Prime, so the poll
/// cadence does not phase-lock with power-of-two task-tree shapes.
const INJECT_PERIOD: u32 = 61;

/// Registers this worker with the supervisor if its thread dies to a
/// panic that escaped the task catch. Declared first in `worker_loop`,
/// so it drops last during an unwind — after any other drop glue.
struct DeathWatch {
    wid: usize,
    shared: Arc<ExecShared>,
}

impl Drop for DeathWatch {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return;
        }
        // The dying thread can no longer be mid-steal: release the
        // bracket so quiescent buffer reclamation is not wedged forever.
        self.shared.in_steal[self.wid].store(false, Ordering::SeqCst);
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        plock(&self.shared.dead_workers).push(self.wid);
        self.shared.sup_cv.notify_all();
    }
}

pub(crate) fn worker_loop(wid: usize, shared: Arc<ExecShared>) {
    let watch = DeathWatch { wid, shared };
    let shared: &ExecShared = &watch.shared;
    if obs::trace_enabled() {
        obs::trace::set_thread_name(&format!("ws-worker-{wid}"));
    }
    let nworkers = shared.deques.len();
    let steal_tries = shared.config.ws.steal_tries.max(1);
    let mut rng = crate::util::rng::Rng::new(0x5EED ^ wid as u64);
    // Per-worker kernel frame stack, reused across tasks and jobs: task
    // dispatch allocates nothing on the hot path. (`run_kernel` resets
    // it at entry, so a frame left behind by a caught panic is benign.)
    let mut stack = KStack::new();
    let mut backoff: u32 = 0;
    let mut since_inject: u32 = 0;
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // 0. Fairness: service the round-robin injector periodically even
        // while the local deque is hot, so a resident job's task flood
        // cannot starve a freshly admitted root or overflow lane.
        if since_inject >= INJECT_PERIOD {
            since_inject = 0;
            obs::metrics::counter_add("ws.injector_polls", 1);
            if obs::trace_enabled() {
                obs::trace::instant("injector-poll", "ws", Vec::new());
            }
            if let Some(task) = shared.pop_injected() {
                backoff = 0;
                execute(wid, shared, task, &mut stack);
                continue;
            }
        }
        // 1. Own deque (LIFO hot end, lock-free owner path).
        if let Some(task) = shared.deques[wid].pop() {
            backoff = 0;
            since_inject += 1;
            execute(wid, shared, task, &mut stack);
            continue;
        }
        // 2. Injector lanes (new job roots, per-job spawn overflow).
        if let Some(task) = shared.pop_injected() {
            backoff = 0;
            since_inject = 0;
            execute(wid, shared, task, &mut stack);
            continue;
        }
        // Chaos steal-seam faults: the one-shot worker kill fires here —
        // deliberately *outside* the task catch and *before* the
        // `in_steal` bracket, so the thread actually dies (DeathWatch
        // hands it to the supervisor) without wedging reclamation or
        // losing a task.
        steal_seam_faults(shared, wid, &mut rng);
        // 3. Steal (FIFO cold end of random victims, CAS only). The
        // in_steal flag brackets the window in which this thief may hold
        // a victim's buffer pointer — the executor's quiescent
        // reclamation of retired buffers keys off it.
        if nworkers > 1 {
            shared.in_steal[wid].store(true, Ordering::SeqCst);
            let mut stolen = None;
            for _ in 0..steal_tries {
                let victim = rng.below(nworkers as u64) as usize;
                if victim == wid {
                    continue;
                }
                if let Some(t) = shared.deques[victim].steal() {
                    stolen = Some(t);
                    break;
                }
            }
            shared.in_steal[wid].store(false, Ordering::SeqCst);
            if let Some(task) = stolen {
                backoff = 0;
                since_inject += 1;
                task.job.counters.steals.fetch_add(1, Ordering::Relaxed);
                if obs::trace_enabled() {
                    obs::trace::instant(
                        "steal",
                        "ws",
                        vec![("job", ArgVal::I64(task.job.id.0 as i64))],
                    );
                }
                execute(wid, shared, task, &mut stack);
                continue;
            }
        }
        // 4. Flush pending xla batch work across active jobs.
        if flush_xla(wid, shared) {
            backoff = 0;
            continue;
        }
        // 5. Exponential backoff: spin a few rounds, then park with a
        // growing timeout (pushers notify; the idle counter gates the
        // futex syscall on the push path).
        if backoff < SPIN_ROUNDS {
            for _ in 0..(8u32 << backoff) {
                std::hint::spin_loop();
            }
            backoff += 1;
            continue;
        }
        let park_us = 50u64 << (backoff - SPIN_ROUNDS).min(MAX_PARK_SHIFT);
        obs::metrics::counter_add("ws.parks", 1);
        if obs::trace_enabled() {
            obs::trace::instant("park", "ws", vec![("us", ArgVal::I64(park_us as i64))]);
        }
        backoff = backoff.saturating_add(1);
        shared.idle_workers.fetch_add(1, Ordering::SeqCst);
        let guard = plock(&shared.idle_lock);
        let _ = shared
            .idle_cv
            .wait_timeout(guard, Duration::from_micros(park_us))
            .unwrap_or_else(|p| p.into_inner());
        shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Fault-plan hooks on the steal seam: the one-shot worker kill, plus
/// timing-only jitter on the contended path.
fn steal_seam_faults(shared: &ExecShared, wid: usize, rng: &mut crate::util::rng::Rng) {
    let Some(fs) = &shared.fault else { return };
    if let Some((kill_wid, after)) = fs.plan.kill_worker {
        if kill_wid == wid {
            let n = fs.steal_clock.fetch_add(1, Ordering::Relaxed) + 1;
            if n >= after && fs.kill_armed.swap(false, Ordering::SeqCst) {
                obs::metrics::counter_add("ws.workers_killed", 1);
                panic!("chaos: injected death of worker {wid}");
            }
        }
    }
    // Sub-scale the dispatch delay rate: the steal seam runs far hotter
    // than any single job's dispatch stream.
    if fs.plan.delay_rate > 0.0 && rng.chance(fs.plan.delay_rate * 0.05) {
        std::thread::sleep(Duration::from_micros(1 + rng.below(30)));
    }
}

/// Flush queued xla instances through each active job's batch sink.
/// Returns true if any work was done.
fn flush_xla(wid: usize, shared: &ExecShared) -> bool {
    if shared.xla_pending.load(Ordering::SeqCst) == 0 {
        return false;
    }
    let mut did = false;
    for job in shared.active_jobs() {
        did |= flush_job_xla(wid, shared, &job);
    }
    did
}

/// Drain one job's xla queue through its batch sink. Arguments and
/// continuations are *moved* out of the queued instances — the queue
/// already holds the owned `Vec<Value>` rows the sink consumes (staged
/// at spawn from the kernel's arg-staging slots), so the flush performs
/// no per-instance `ArgList` conversion; task names are borrowed from
/// the kernels.
///
/// Accounting contract: every drained instance is `finish_one`d exactly
/// once, whether it was delivered, skipped on abort, or orphaned by a
/// sink error or caught panic — per-job completion counters tolerate no
/// leaks (which is why the `finish_one` loop sits outside the catch).
fn flush_job_xla(wid: usize, shared: &ExecShared, job: &Arc<JobState>) -> bool {
    let mut batch: Vec<(FuncId, Vec<Value>, Cont)> = {
        let mut q = plock(&job.xla_queue);
        if q.is_empty() {
            return false;
        }
        let take = q.len().min(job.xla_sink.preferred_batch());
        q.drain(..take).collect()
    };
    let drained = batch.len();
    shared.xla_pending.fetch_sub(drained as u64, Ordering::SeqCst);
    if !job.is_aborted() {
        // UNWIND SAFETY: the closure mutates only `batch` (local, dropped
        // below without further reads of moved-from entries) and per-job
        // shared state whose invariants hold across a mid-flush unwind:
        // counters are monotonic atomics, `deliver` completes each
        // fill/release before returning, and the drained instances are
        // finish_one'd outside the catch regardless.
        let caught = panic::catch_unwind(AssertUnwindSafe(|| {
            flush_groups(wid, shared, job, &mut batch)
        }));
        if let Err(payload) = caught {
            let msg = panic_message(payload);
            obs::metrics::counter_add("ws.panics_caught", 1);
            if obs::trace_enabled() {
                obs::trace::instant(
                    "panic-caught",
                    "ws",
                    vec![("job", ArgVal::I64(job.id.0 as i64))],
                );
            }
            fail_job(shared, job, JobError::panicked(job.id, &msg));
        }
    }
    drop(batch);
    for _ in 0..drained {
        finish_one(shared, job);
    }
    true
}

/// The sink-facing half of an xla flush: group by task id (preserving
/// order within each group), execute each group as one batch, deliver
/// the results. Runs inside the flush catch.
fn flush_groups(
    wid: usize,
    shared: &ExecShared,
    job: &Arc<JobState>,
    batch: &mut [(FuncId, Vec<Value>, Cont)],
) {
    // The per-job fault clock ticks once per flushed batch, so the xla
    // seam participates in the deterministic plan (flush timing is
    // scheduler-dependent — outcome determinism is only guaranteed for
    // jobs without xla tasks).
    if job.metered() {
        let tick = job.fault_tick();
        match job.injected_fault(tick) {
            Some(InjectedFault::Panic) => {
                panic!("chaos: injected panic in {} at xla flush (tick {tick})", job.id);
            }
            Some(InjectedFault::Transient) => {
                fail_job(
                    shared,
                    job,
                    JobError::transient(format!(
                        "chaos: injected transient fault in {} at xla flush (tick {tick})",
                        job.id
                    )),
                );
                return;
            }
            None => {}
        }
        if let Some(us) = job.injected_delay(tick) {
            std::thread::sleep(Duration::from_micros(us));
        }
    }
    let mut groups: Vec<(FuncId, Vec<usize>)> = Vec::new();
    for (i, (fid, _, _)) in batch.iter().enumerate() {
        match groups.iter_mut().find(|(g, _)| g == fid) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((*fid, vec![i])),
        }
    }
    'groups: for (fid, idxs) in groups {
        let name = &job.kernels.kernel(fid).name;
        let args: Vec<Vec<Value>> = idxs.iter().map(|&i| std::mem::take(&mut batch[i].1)).collect();
        job.counters.xla_batches.fetch_add(1, Ordering::Relaxed);
        job.counters.xla_tasks.fetch_add(idxs.len() as u64, Ordering::Relaxed);
        match job.xla_sink.exec_batch(name, &args, &job.memory) {
            Ok(results) => {
                if results.len() != idxs.len() {
                    fail_job(
                        shared,
                        job,
                        JobError::internal(format!(
                            "xla sink returned {} results for {} instances of `{name}`",
                            results.len(),
                            idxs.len()
                        )),
                    );
                    break 'groups;
                }
                for (&i, value) in idxs.iter().zip(results) {
                    let cont = std::mem::replace(&mut batch[i].2, Cont::Root);
                    if let Err(e) = deliver(wid, shared, job, cont, value) {
                        fail_job(shared, job, JobError::classify(&e));
                        break 'groups;
                    }
                }
            }
            Err(e) => {
                fail_job(shared, job, JobError::classify(&e));
                break 'groups;
            }
        }
    }
}

/// Render a `catch_unwind` payload for the structured error message.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn execute(wid: usize, shared: &ExecShared, task: WsTask, stack: &mut KStack) {
    let job = Arc::clone(&task.job);
    if job.is_aborted() {
        // Discard without running; the task's continuation (and any
        // closures it holds) drops here, the arena sweep at completion
        // reclaims the rest.
        obs::metrics::counter_add("ws.cancel_sweeps", 1);
        if obs::trace_enabled() {
            obs::trace::instant(
                "cancel-sweep",
                "ws",
                vec![("job", ArgVal::I64(job.id.0 as i64))],
            );
        }
        drop(task);
        finish_one(shared, &job);
        return;
    }
    job.counters.tasks_run.fetch_add(1, Ordering::Relaxed);
    // The per-task dispatch span: a `B`/`E` pair on this worker's tid,
    // tagged with the owning job so job async spans nest their children.
    let span_name: Option<String> = if obs::trace_enabled() {
        if !job.first_dispatched.swap(true, Ordering::Relaxed) {
            obs::trace::async_instant("first-dispatch", "job", job.id.0, Vec::new());
        }
        let name = job.kernels.kernel(task.task).name.clone();
        obs::trace::begin_args(
            name.clone(),
            "task",
            vec![("job", ArgVal::I64(job.id.0 as i64))],
        );
        Some(name)
    } else {
        None
    };
    let retired_before = stack.retired();
    // Panic isolation: contain a panicking task to its own job.
    // UNWIND SAFETY (AssertUnwindSafe): the only state observable after
    // an unwind here is (1) `stack` — `run_kernel` clears it at the next
    // entry, so torn frames are unreachable; (2) the job's shared memory
    // and counters — word-atomic / monotonic, no multi-word invariant to
    // tear; (3) the job's closure registry — its mutexes are
    // poison-tolerant (`plock`) and its per-entry invariants are updated
    // before links are published, and the job is failed below so no new
    // task of it will resolve half-built handles.
    let caught = panic::catch_unwind(AssertUnwindSafe(|| run_task(wid, shared, &job, task, stack)));
    job.counters.instrs.fetch_add(stack.retired() - retired_before, Ordering::Relaxed);
    if let Some(name) = span_name {
        obs::trace::end(name, "task");
    }
    match caught {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            // An aborted task's dispatch-boundary bail is expected noise;
            // anything else is the job's first real error (counted failed
            // at fail time, not at graph drain — unless its kind arms a
            // retry).
            if !job.is_aborted() {
                fail_job(shared, &job, JobError::classify(&e));
            }
        }
        Err(payload) => {
            let msg = panic_message(payload);
            obs::metrics::counter_add("ws.panics_caught", 1);
            if obs::trace_enabled() {
                obs::trace::instant(
                    "panic-caught",
                    "ws",
                    vec![("job", ArgVal::I64(job.id.0 as i64))],
                );
            }
            fail_job(shared, &job, JobError::panicked(job.id, &msg));
        }
    }
    finish_one(shared, &job);
}

/// Push a new runnable task (pending already incremented by caller).
/// Within budget it lands on this worker's own deque; a job past its
/// in-flight budget overflows into its round-robin injector lane so it
/// cannot monopolize the pool.
fn push_task(wid: usize, shared: &ExecShared, task: WsTask) {
    if task.job.pending.load(Ordering::Relaxed) > shared.config.max_inflight_per_job as u64 {
        shared.inject(task);
        return;
    }
    shared.deques[wid].push(task);
    shared.notify_if_idle();
}

fn deliver(
    wid: usize,
    shared: &ExecShared,
    job: &Arc<JobState>,
    cont: Cont,
    value: Value,
) -> Result<()> {
    match cont {
        Cont::Root => {
            let mut slot = plock(&job.result);
            if slot.is_some() {
                bail!("root continuation received two results");
            }
            *slot = Some(value);
        }
        Cont::Slot { clos, slot } => {
            clos.fill(slot, value);
            if clos.release() {
                fire(wid, shared, job, &clos);
            }
        }
        Cont::Counter { clos } => {
            if clos.release() {
                fire(wid, shared, job, &clos);
            }
        }
    }
    Ok(())
}

fn fire(wid: usize, shared: &ExecShared, job: &Arc<JobState>, clos: &Arc<SharedClosure>) {
    let handle = clos.handle.load(Ordering::Relaxed);
    if handle >= 0 {
        job.registry.remove(handle);
    }
    let task = WsTask {
        job: Arc::clone(job),
        task: clos.task,
        args: clos.take_args(),
        cont: clos.take_cont(),
    };
    job.pending.fetch_add(1, Ordering::AcqRel);
    push_task(wid, shared, task);
}

/// The worker's [`Machine`]: per-job closure registry + shared memory
/// effects, plus the metered cooperative dispatch boundary
/// (abort/cancel, deadline, fuel, fault injection).
struct WsMachine<'a> {
    wid: usize,
    shared: &'a ExecShared,
    job: &'a Arc<JobState>,
    cont: Cont,
}

fn run_task(
    wid: usize,
    shared: &ExecShared,
    job: &Arc<JobState>,
    inst: WsTask,
    stack: &mut KStack,
) -> Result<()> {
    let kernel = job.kernels.kernel(inst.task);

    if kernel.kind == FuncKind::Xla {
        // Shouldn't reach a deque (spawns route xla tasks to the batch
        // queue) — but a root xla task arrives here; run it as a batch of 1.
        let out = job
            .xla_sink
            .exec_batch(&kernel.name, &[inst.args.into_vec()], &job.memory)?
            .pop()
            .ok_or_else(|| anyhow!("empty xla result"))?;
        return deliver(wid, shared, job, inst.cont, out);
    }

    let mut machine = WsMachine { wid, shared, job, cont: inst.cont };
    let value = run_kernel(
        &job.kernels,
        inst.task,
        inst.args.as_slice(),
        stack,
        &mut machine,
        100_000_000,
    )?;
    if kernel.kind == FuncKind::Leaf {
        // A spawned leaf: its sequential return value is the send.
        let cont = machine.cont;
        return deliver(wid, shared, job, cont, value);
    }
    Ok(())
}

impl<'a> WsMachine<'a> {
    /// Resolve a closure handle through the non-panicking lookup: a
    /// stale handle (fired, swept, or recycled slot) becomes a
    /// structured `Trap::StaleClosure` job failure instead of killing
    /// the process. (`Registry::get` keeps the loud panic for tests and
    /// debug paths that want the old fail-stop behavior.)
    fn resolve(&self, clos: Value) -> Result<Arc<SharedClosure>> {
        self.job
            .registry
            .lookup(clos.as_i64())
            .map_err(|stale| anyhow!("{stale}"))
    }

    /// The slow half of the dispatch boundary, entered only for metered
    /// jobs (deadline, fuel budget, or an armed fault schedule): one
    /// fault-clock tick, then injected fault → injected delay → fuel →
    /// deadline, in that order — injection first keeps the fault
    /// schedule independent of the budget settings.
    #[cold]
    fn meter_tick(&mut self) -> Result<()> {
        let job = self.job;
        let tick = job.fault_tick();
        match job.injected_fault(tick) {
            Some(InjectedFault::Panic) => {
                panic!("chaos: injected panic in {} at dispatch {tick}", job.id);
            }
            Some(InjectedFault::Transient) => {
                bail!("chaos: injected transient fault in {} at dispatch {tick}", job.id);
            }
            None => {}
        }
        if let Some(us) = job.injected_delay(tick) {
            std::thread::sleep(Duration::from_micros(us));
        }
        if let Some(budget) = job.spec.fuel_budget {
            if tick > budget {
                return Err(JobError::fuel_budget(job.id, budget).into());
            }
        }
        // The deadline clock syscall is amortized: checked on the first
        // tick and every 64th after.
        if tick == 1 || tick & 63 == 0 {
            if let Some(deadline_at) = job.deadline_at() {
                if Instant::now() >= deadline_at {
                    let budget = job.spec.deadline.unwrap_or_default();
                    return Err(JobError::deadline(job.id, budget).into());
                }
            }
        }
        Ok(())
    }
}

impl<'a> Machine for WsMachine<'a> {
    fn jit(&mut self) -> Option<Arc<crate::exec::jit::JitTier>> {
        self.job.jit.clone()
    }

    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
        self.job.memory.load(arr, index)
    }

    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.job.memory.store(arr, index, value)
    }

    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.job.memory.atomic_add(arr, index, value)
    }

    fn on_dispatch(&mut self, fid: FuncId, _depth: usize) -> Result<()> {
        // The cooperative abort boundary: one relaxed load per frame
        // entry, so an aborted (cancelled/failed/retrying) job's running
        // tasks unwind at the next dispatch instead of draining their
        // whole subtree.
        if self.job.is_aborted() {
            bail!("{} cancelled at dispatch boundary", self.job.id);
        }
        // Deadline/fuel/fault metering, gated to one relaxed load for
        // unmetered jobs so the clean hot path stays unchanged.
        if self.job.metered() {
            self.meter_tick()?;
        }
        // Hotness profile: once per frame entry (never per retired
        // instruction), behind one relaxed load when disabled.
        if obs::profile_enabled() {
            obs::profile::hit(&self.job.kernels.kernel(fid).name);
        }
        Ok(())
    }

    fn make_closure(&mut self, task: FuncId) -> Result<Value> {
        if let Some(budget) = self.job.spec.max_live_closures {
            if self.job.registry.live() >= budget {
                return Err(JobError::closure_budget(self.job.id, budget).into());
            }
        }
        self.job.counters.closures_made.fetch_add(1, Ordering::Relaxed);
        let slot_tys = Arc::clone(&self.job.kernels.kernel(task).param_tys);
        let clos = Arc::new(SharedClosure::new(task, slot_tys, self.cont.clone()));
        let handle = self.job.registry.insert(clos.clone(), self.wid);
        clos.handle.store(handle, Ordering::Relaxed);
        Ok(Value::I64(handle))
    }

    fn closure_store(&mut self, clos: Value, field: u32, value: Value) -> Result<()> {
        self.resolve(clos)?.fill(field, value);
        Ok(())
    }

    fn spawn_child(&mut self, callee: FuncId, args: &[Value], ret: KontRef) -> Result<()> {
        let cont = match ret {
            KontRef::Slot { clos, field } => {
                let c = self.resolve(clos)?;
                c.hold();
                Cont::Slot { clos: c, slot: field }
            }
            KontRef::Counter { clos } => {
                let c = self.resolve(clos)?;
                c.hold();
                Cont::Counter { clos: c }
            }
            KontRef::Forward => self.cont.clone(),
        };
        self.job.pending.fetch_add(1, Ordering::AcqRel);
        if self.job.kernels.kernel(callee).kind == FuncKind::Xla {
            // `args` is the spawner's kernel arg-staging slot slice: copy
            // it straight into the owned row the batch sink will consume
            // (no ArgList intermediary to convert at flush time). The row
            // is built before taking the queue lock so the allocation
            // never sits inside the shared critical section.
            let row = args.to_vec();
            plock(&self.job.xla_queue).push((callee, row, cont));
            self.shared.xla_pending.fetch_add(1, Ordering::SeqCst);
            // Same idle gate as push_task: pay the futex only when a
            // worker actually sleeps.
            self.shared.notify_if_idle();
        } else {
            push_task(
                self.wid,
                self.shared,
                WsTask {
                    job: Arc::clone(self.job),
                    task: callee,
                    args: ArgList::from_slice(args),
                    cont,
                },
            );
        }
        Ok(())
    }

    fn close_spawns(&mut self, clos: Value) -> Result<()> {
        let c = self.resolve(clos)?;
        if c.release() {
            fire(self.wid, self.shared, self.job, &c);
        }
        Ok(())
    }

    fn send_argument(&mut self, value: Value) -> Result<()> {
        deliver(self.wid, self.shared, self.job, self.cont.clone(), value)
    }
}
