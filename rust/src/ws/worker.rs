//! Worker loop and task interpretation for the WS runtime.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::ir::cfg::{FuncId, FuncKind, Op, RetTarget, Term};
use crate::ir::expr::{self, Value, VarId};

use super::closure::{Cont, SharedClosure};
use super::{Shared, WsConfig, WsStats};

/// A runnable task instance.
#[derive(Clone, Debug)]
pub struct WsTask {
    pub task: FuncId,
    pub args: Vec<Value>,
    pub cont: Cont,
}

pub(crate) fn worker_loop(wid: usize, shared: &Shared<'_>, config: &WsConfig, stats: &mut WsStats) {
    let nworkers = shared.deques.len();
    let mut rng = crate::util::rng::Rng::new(0x5EED ^ wid as u64);
    // Per-worker environment scratch, reused across tasks (perf: saves one
    // allocation per task on the hot path — see EXPERIMENTS.md §Perf).
    let mut env_scratch: Vec<Value> = Vec::with_capacity(64);
    loop {
        if shared.done.load(Ordering::SeqCst) {
            return;
        }
        // 1. Own deque (LIFO hot end).
        let task = shared.deques[wid].lock().unwrap().pop_back();
        if let Some(task) = task {
            execute(wid, shared, task, stats, &mut env_scratch);
            continue;
        }
        // 2. Steal (FIFO cold end of a random victim).
        let mut stolen = None;
        for _ in 0..config.steal_tries.max(1) {
            let victim = rng.below(nworkers as u64) as usize;
            if victim == wid {
                continue;
            }
            if let Some(t) = shared.deques[victim].lock().unwrap().pop_front() {
                stolen = Some(t);
                break;
            }
        }
        if let Some(task) = stolen {
            stats.steals += 1;
            execute(wid, shared, task, stats, &mut env_scratch);
            continue;
        }
        // 3. Flush pending xla batch work.
        if flush_xla(wid, shared, stats) {
            continue;
        }
        // 4. Park briefly; pushers notify (gated on the idle counter so
        // the hot path skips the futex syscall when nobody sleeps).
        shared.idle_workers.fetch_add(1, Ordering::SeqCst);
        let guard = shared.idle_lock.lock().unwrap();
        let _ = shared
            .idle_cv
            .wait_timeout(guard, Duration::from_micros(200))
            .unwrap();
        shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Drain the xla queue through the batch sink. Returns true if any work was
/// done.
fn flush_xla(wid: usize, shared: &Shared<'_>, stats: &mut WsStats) -> bool {
    let batch: Vec<(FuncId, Vec<Value>, Cont)> = {
        let mut q = shared.xla_queue.lock().unwrap();
        if q.is_empty() {
            return false;
        }
        let take = q.len().min(shared.xla_sink.preferred_batch());
        q.drain(..take).collect()
    };
    // Group by task id, preserving order within each group.
    let mut groups: Vec<(FuncId, Vec<usize>)> = Vec::new();
    for (i, (fid, _, _)) in batch.iter().enumerate() {
        match groups.iter_mut().find(|(g, _)| g == fid) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((*fid, vec![i])),
        }
    }
    for (fid, idxs) in groups {
        let name = shared.module.funcs[fid].name.clone();
        let args: Vec<Vec<Value>> = idxs.iter().map(|&i| batch[i].1.clone()).collect();
        stats.xla_batches += 1;
        stats.xla_tasks += idxs.len() as u64;
        match shared.xla_sink.exec_batch(&name, &args, &shared.memory) {
            Ok(results) => {
                if results.len() != idxs.len() {
                    shared.fail(anyhow!(
                        "xla sink returned {} results for {} instances of `{name}`",
                        results.len(),
                        idxs.len()
                    ));
                    return true;
                }
                for (&i, value) in idxs.iter().zip(results) {
                    let cont = batch[i].2.clone();
                    if let Err(e) = deliver(wid, shared, cont, value) {
                        shared.fail(e);
                        return true;
                    }
                    finish_one(shared);
                }
            }
            Err(e) => {
                shared.fail(e);
                return true;
            }
        }
    }
    true
}

fn execute(
    wid: usize,
    shared: &Shared<'_>,
    task: WsTask,
    stats: &mut WsStats,
    env_scratch: &mut Vec<Value>,
) {
    stats.tasks_run += 1;
    if let Err(e) = run_task(wid, shared, task, stats, env_scratch) {
        shared.fail(e);
        return;
    }
    finish_one(shared);
}

/// Decrement pending; on zero, signal completion.
fn finish_one(shared: &Shared<'_>) {
    if shared.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        shared.done.store(true, Ordering::SeqCst);
        shared.idle_cv.notify_all();
    }
}

/// Push a new runnable task (pending already incremented by caller).
fn push_task(wid: usize, shared: &Shared<'_>, task: WsTask) {
    shared.deques[wid].lock().unwrap().push_back(task);
    if shared.idle_workers.load(Ordering::Relaxed) > 0 {
        shared.idle_cv.notify_one();
    }
}

fn deliver(wid: usize, shared: &Shared<'_>, cont: Cont, value: Value) -> Result<()> {
    match cont {
        Cont::Root => {
            let mut slot = shared.result.lock().unwrap();
            if slot.is_some() {
                bail!("root continuation received two results");
            }
            *slot = Some(value);
        }
        Cont::Slot { clos, slot } => {
            clos.fill(slot, value);
            if clos.release() {
                fire(wid, shared, &clos);
            }
        }
        Cont::Counter { clos } => {
            if clos.release() {
                fire(wid, shared, &clos);
            }
        }
    }
    Ok(())
}

fn fire(wid: usize, shared: &Shared<'_>, clos: &Arc<SharedClosure>) {
    let handle = clos.handle.load(Ordering::Relaxed);
    if handle >= 0 {
        shared.registry.remove(handle);
    }
    let task = WsTask { task: clos.task, args: clos.take_args(), cont: clos.take_cont() };
    shared.pending.fetch_add(1, Ordering::AcqRel);
    push_task(wid, shared, task);
}

fn run_task(
    wid: usize,
    shared: &Shared<'_>,
    inst: WsTask,
    stats: &mut WsStats,
    env_scratch: &mut Vec<Value>,
) -> Result<()> {
    let module = shared.module;
    let func = &module.funcs[inst.task];

    if func.kind == FuncKind::Xla {
        // Shouldn't reach a deque (spawns route xla tasks to the batch
        // queue) — but a root xla task arrives here; run it as a batch of 1.
        let out = shared
            .xla_sink
            .exec_batch(&func.name, &[inst.args.clone()], &shared.memory)?
            .pop()
            .ok_or_else(|| anyhow!("empty xla result"))?;
        return deliver(wid, shared, inst.cont, out);
    }
    if func.kind == FuncKind::Leaf {
        let out = eval_leaf(shared, inst.task, &inst.args)?;
        return deliver(wid, shared, inst.cont, out);
    }

    let cfg = func.cfg();
    if inst.args.len() != func.params {
        bail!(
            "task `{}` expects {} args, got {} (closure layout bug)",
            func.name,
            func.params,
            inst.args.len()
        );
    }
    env_scratch.clear();
    env_scratch.extend(func.vars.values().map(|v| Value::zero_of(v.ty)));
    let env = env_scratch;
    for (i, a) in inst.args.iter().enumerate() {
        env[i] = a.coerce(func.vars[VarId::new(i)].ty);
    }

    let mut block = cfg.entry;
    let mut steps = 0u64;
    loop {
        steps += 1;
        if steps > 100_000_000 {
            bail!("task `{}` exceeded step limit", func.name);
        }
        let b = &cfg.blocks[block];
        for op in &b.ops {
            match op {
                Op::Assign { dst, src } => {
                    let v = expr::eval(src, &|v| env[v.index()]);
                    env[dst.index()] = v.coerce(func.vars[*dst].ty);
                }
                Op::Load { dst, arr, index, .. } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    env[dst.index()] = shared.memory.load(*arr, idx)?;
                }
                Op::Store { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    shared.memory.store(*arr, idx, val)?;
                }
                Op::AtomicAdd { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    shared.memory.atomic_add(*arr, idx, val)?;
                }
                Op::Call { dst, callee, args } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                    let r = eval_leaf(shared, *callee, &vals)?;
                    if let Some(d) = dst {
                        env[d.index()] = r.coerce(func.vars[*d].ty);
                    }
                }
                Op::MakeClosure { dst, task } => {
                    stats.closures_made += 1;
                    let t = &module.funcs[*task];
                    let slot_tys: Vec<_> = t.param_ids().map(|p| t.vars[p].ty).collect();
                    let clos =
                        Arc::new(SharedClosure::new(*task, slot_tys, inst.cont.clone()));
                    let handle = shared.registry.insert(clos.clone(), wid);
                    clos.handle.store(handle, Ordering::Relaxed);
                    env[dst.index()] = Value::I64(handle);
                }
                Op::ClosureStore { clos, field, value } => {
                    let h = env[clos.index()].as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    shared.registry.get(h).fill(*field, val);
                }
                Op::SpawnChild { callee, args, ret } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                    let cont = match ret {
                        RetTarget::Slot { clos, field } => {
                            let c = shared.registry.get(env[clos.index()].as_i64());
                            c.hold();
                            Cont::Slot { clos: c, slot: *field }
                        }
                        RetTarget::Counter { clos } => {
                            let c = shared.registry.get(env[clos.index()].as_i64());
                            c.hold();
                            Cont::Counter { clos: c }
                        }
                        RetTarget::Forward => inst.cont.clone(),
                    };
                    shared.pending.fetch_add(1, Ordering::AcqRel);
                    if module.funcs[*callee].kind == FuncKind::Xla {
                        shared.xla_queue.lock().unwrap().push((*callee, vals, cont));
                        shared.idle_cv.notify_one();
                    } else {
                        push_task(wid, shared, WsTask { task: *callee, args: vals, cont });
                    }
                }
                Op::CloseSpawns { clos } => {
                    let c = shared.registry.get(env[clos.index()].as_i64());
                    if c.release() {
                        fire(wid, shared, &c);
                    }
                }
                Op::SendArgument { value } => {
                    let v = match value {
                        Some(e) => expr::eval(e, &|v| env[v.index()]).coerce(func.ret),
                        None => Value::Unit,
                    };
                    deliver(wid, shared, inst.cont.clone(), v)?;
                }
                Op::Spawn { .. } => bail!("implicit Spawn in explicit IR"),
            }
        }
        match &b.term {
            Term::Jump(next) => block = *next,
            Term::Branch { cond, then_, else_ } => {
                let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                block = if c { *then_ } else { *else_ };
            }
            Term::Halt => return Ok(()),
            other => bail!("non-explicit terminator {other:?} in task `{}`", func.name),
        }
    }
}

fn eval_leaf(shared: &Shared<'_>, fid: FuncId, args: &[Value]) -> Result<Value> {
    let func = &shared.module.funcs[fid];
    if func.kind != FuncKind::Leaf {
        bail!("sequential call to non-leaf `{}`", func.name);
    }
    let cfg = func.cfg();
    let mut env: Vec<Value> = func.vars.values().map(|v| Value::zero_of(v.ty)).collect();
    for (i, a) in args.iter().enumerate() {
        env[i] = a.coerce(func.vars[VarId::new(i)].ty);
    }
    let mut block = cfg.entry;
    let mut steps = 0u64;
    loop {
        steps += 1;
        if steps > 100_000_000 {
            bail!("leaf `{}` exceeded step limit", func.name);
        }
        let b = &cfg.blocks[block];
        for op in &b.ops {
            match op {
                Op::Assign { dst, src } => {
                    let v = expr::eval(src, &|v| env[v.index()]);
                    env[dst.index()] = v.coerce(func.vars[*dst].ty);
                }
                Op::Load { dst, arr, index, .. } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    env[dst.index()] = shared.memory.load(*arr, idx)?;
                }
                Op::Store { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    shared.memory.store(*arr, idx, val)?;
                }
                Op::AtomicAdd { arr, index, value } => {
                    let idx = expr::eval(index, &|v| env[v.index()]).as_i64();
                    let val = expr::eval(value, &|v| env[v.index()]);
                    shared.memory.atomic_add(*arr, idx, val)?;
                }
                Op::Call { dst, callee, args } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| expr::eval(a, &|v| env[v.index()])).collect();
                    let r = eval_leaf(shared, *callee, &vals)?;
                    if let Some(d) = dst {
                        env[d.index()] = r.coerce(func.vars[*d].ty);
                    }
                }
                other => bail!("op {other:?} not allowed in leaf `{}`", func.name),
            }
        }
        match &b.term {
            Term::Jump(next) => block = *next,
            Term::Branch { cond, then_, else_ } => {
                let c = expr::eval(cond, &|v| env[v.index()]).as_bool();
                block = if c { *then_ } else { *else_ };
            }
            Term::Return(value) => {
                return Ok(match value {
                    Some(e) => expr::eval(e, &|v| env[v.index()]).coerce(func.ret),
                    None => Value::Unit,
                })
            }
            other => bail!("terminator {other:?} not allowed in leaf `{}`", func.name),
        }
    }
}
