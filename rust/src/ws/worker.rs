//! Worker loop and kernel-machine task execution for the WS runtime.
//!
//! Each worker owns a lock-free Chase–Lev deque ([`super::deque`]): its
//! own pushes/pops touch no lock, thieves CAS the cold end. Task bodies
//! run on the shared compiled kernels ([`crate::exec`]) through
//! [`WsMachine`], whose side effects are the concurrent closure registry
//! and the word-atomic shared memory. Idle thieves back off
//! exponentially (spin first, then park on the idle condvar with a
//! growing timeout) so contended steals never spin hot and the push
//! path pays a futex only when somebody actually sleeps.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::exec::{run_kernel, ArgList, KStack, KontRef, Machine};
use crate::ir::cfg::{FuncId, FuncKind, GlobalId};
use crate::ir::expr::Value;

use super::closure::{Cont, SharedClosure};
use super::{Shared, WsConfig, WsStats};

/// A runnable task instance.
#[derive(Clone, Debug)]
pub struct WsTask {
    pub task: FuncId,
    pub args: ArgList,
    pub cont: Cont,
}

/// Spin rounds before a thief starts parking.
const SPIN_ROUNDS: u32 = 6;
/// Cap on the parking-backoff exponent (50us << 2 = 200us max — the
/// notify race between a push's `idle_workers` check and a parker's
/// increment is bounded by the timeout, so the cap keeps the worst-case
/// lost-wakeup latency at the pre-rework 200us bound).
const MAX_PARK_SHIFT: u32 = 2;

pub(crate) fn worker_loop(wid: usize, shared: &Shared, config: &WsConfig, stats: &mut WsStats) {
    let nworkers = shared.deques.len();
    let mut rng = crate::util::rng::Rng::new(0x5EED ^ wid as u64);
    // Per-worker kernel frame stack, reused across tasks: task dispatch
    // allocates nothing on the hot path.
    let mut stack = KStack::new();
    let mut backoff: u32 = 0;
    loop {
        if shared.done.load(Ordering::SeqCst) {
            stats.instrs = stack.retired();
            return;
        }
        // 1. Own deque (LIFO hot end, lock-free owner path).
        if let Some(task) = shared.deques[wid].pop() {
            backoff = 0;
            execute(wid, shared, task, stats, &mut stack);
            continue;
        }
        // 2. Steal (FIFO cold end of random victims, CAS only).
        let mut stolen = None;
        for _ in 0..config.steal_tries.max(1) {
            let victim = rng.below(nworkers as u64) as usize;
            if victim == wid {
                continue;
            }
            if let Some(t) = shared.deques[victim].steal() {
                stolen = Some(t);
                break;
            }
        }
        if let Some(task) = stolen {
            backoff = 0;
            stats.steals += 1;
            execute(wid, shared, task, stats, &mut stack);
            continue;
        }
        // 3. Flush pending xla batch work.
        if flush_xla(wid, shared, stats) {
            backoff = 0;
            continue;
        }
        // 4. Exponential backoff: spin a few rounds, then park with a
        // growing timeout (pushers notify; the idle counter gates the
        // futex syscall on the push path).
        if backoff < SPIN_ROUNDS {
            for _ in 0..(8u32 << backoff) {
                std::hint::spin_loop();
            }
            backoff += 1;
            continue;
        }
        let park_us = 50u64 << (backoff - SPIN_ROUNDS).min(MAX_PARK_SHIFT);
        backoff = backoff.saturating_add(1);
        shared.idle_workers.fetch_add(1, Ordering::SeqCst);
        let guard = shared.idle_lock.lock().unwrap();
        let _ = shared
            .idle_cv
            .wait_timeout(guard, Duration::from_micros(park_us))
            .unwrap();
        shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Drain the xla queue through the batch sink. Returns true if any work
/// was done. Arguments and continuations are *moved* out of the queued
/// instances — the queue already holds the owned `Vec<Value>` rows the
/// sink consumes (staged at spawn from the kernel's arg-staging slots),
/// so the flush performs no per-instance `ArgList` conversion; task
/// names are borrowed from the kernels.
fn flush_xla(wid: usize, shared: &Shared, stats: &mut WsStats) -> bool {
    let mut batch: Vec<(FuncId, Vec<Value>, Cont)> = {
        let mut q = shared.xla_queue.lock().unwrap();
        if q.is_empty() {
            return false;
        }
        let take = q.len().min(shared.xla_sink.preferred_batch());
        q.drain(..take).collect()
    };
    // Group by task id, preserving order within each group.
    let mut groups: Vec<(FuncId, Vec<usize>)> = Vec::new();
    for (i, (fid, _, _)) in batch.iter().enumerate() {
        match groups.iter_mut().find(|(g, _)| g == fid) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((*fid, vec![i])),
        }
    }
    for (fid, idxs) in groups {
        let name = &shared.kernels.kernel(fid).name;
        let args: Vec<Vec<Value>> = idxs
            .iter()
            .map(|&i| std::mem::take(&mut batch[i].1))
            .collect();
        stats.xla_batches += 1;
        stats.xla_tasks += idxs.len() as u64;
        match shared.xla_sink.exec_batch(name, &args, &shared.memory) {
            Ok(results) => {
                if results.len() != idxs.len() {
                    shared.fail(anyhow!(
                        "xla sink returned {} results for {} instances of `{name}`",
                        results.len(),
                        idxs.len()
                    ));
                    return true;
                }
                for (&i, value) in idxs.iter().zip(results) {
                    let cont = std::mem::replace(&mut batch[i].2, Cont::Root);
                    if let Err(e) = deliver(wid, shared, cont, value) {
                        shared.fail(e);
                        return true;
                    }
                    finish_one(shared);
                }
            }
            Err(e) => {
                shared.fail(e);
                return true;
            }
        }
    }
    true
}

fn execute(
    wid: usize,
    shared: &Shared,
    task: WsTask,
    stats: &mut WsStats,
    stack: &mut KStack,
) {
    stats.tasks_run += 1;
    if let Err(e) = run_task(wid, shared, task, stats, stack) {
        shared.fail(e);
        return;
    }
    finish_one(shared);
}

/// Decrement pending; on zero, signal completion.
fn finish_one(shared: &Shared) {
    if shared.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        shared.done.store(true, Ordering::SeqCst);
        shared.idle_cv.notify_all();
    }
}

/// Push a new runnable task onto this worker's own deque (pending already
/// incremented by caller).
fn push_task(wid: usize, shared: &Shared, task: WsTask) {
    shared.deques[wid].push(task);
    if shared.idle_workers.load(Ordering::Relaxed) > 0 {
        shared.idle_cv.notify_one();
    }
}

fn deliver(wid: usize, shared: &Shared, cont: Cont, value: Value) -> Result<()> {
    match cont {
        Cont::Root => {
            let mut slot = shared.result.lock().unwrap();
            if slot.is_some() {
                bail!("root continuation received two results");
            }
            *slot = Some(value);
        }
        Cont::Slot { clos, slot } => {
            clos.fill(slot, value);
            if clos.release() {
                fire(wid, shared, &clos);
            }
        }
        Cont::Counter { clos } => {
            if clos.release() {
                fire(wid, shared, &clos);
            }
        }
    }
    Ok(())
}

fn fire(wid: usize, shared: &Shared, clos: &Arc<SharedClosure>) {
    let handle = clos.handle.load(Ordering::Relaxed);
    if handle >= 0 {
        shared.registry.remove(handle);
    }
    let task = WsTask { task: clos.task, args: clos.take_args(), cont: clos.take_cont() };
    shared.pending.fetch_add(1, Ordering::AcqRel);
    push_task(wid, shared, task);
}

/// The worker's [`Machine`]: closure registry + shared memory effects.
struct WsMachine<'a> {
    wid: usize,
    shared: &'a Shared,
    stats: &'a mut WsStats,
    cont: Cont,
}

fn run_task(
    wid: usize,
    shared: &Shared,
    inst: WsTask,
    stats: &mut WsStats,
    stack: &mut KStack,
) -> Result<()> {
    let kernel = shared.kernels.kernel(inst.task);

    if kernel.kind == FuncKind::Xla {
        // Shouldn't reach a deque (spawns route xla tasks to the batch
        // queue) — but a root xla task arrives here; run it as a batch of 1.
        let out = shared
            .xla_sink
            .exec_batch(&kernel.name, &[inst.args.into_vec()], &shared.memory)?
            .pop()
            .ok_or_else(|| anyhow!("empty xla result"))?;
        return deliver(wid, shared, inst.cont, out);
    }

    let mut machine = WsMachine { wid, shared, stats, cont: inst.cont };
    let value = run_kernel(
        &shared.kernels,
        inst.task,
        inst.args.as_slice(),
        stack,
        &mut machine,
        100_000_000,
    )?;
    if kernel.kind == FuncKind::Leaf {
        // A spawned leaf: its sequential return value is the send.
        let cont = machine.cont;
        return deliver(wid, shared, cont, value);
    }
    Ok(())
}

impl<'a> Machine for WsMachine<'a> {
    fn load(&mut self, arr: GlobalId, index: i64) -> Result<Value> {
        self.shared.memory.load(arr, index)
    }

    fn store(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.shared.memory.store(arr, index, value)
    }

    fn atomic_add(&mut self, arr: GlobalId, index: i64, value: Value) -> Result<()> {
        self.shared.memory.atomic_add(arr, index, value)
    }

    fn make_closure(&mut self, task: FuncId) -> Result<Value> {
        self.stats.closures_made += 1;
        let slot_tys = Arc::clone(&self.shared.kernels.kernel(task).param_tys);
        let clos = Arc::new(SharedClosure::new(task, slot_tys, self.cont.clone()));
        let handle = self.shared.registry.insert(clos.clone(), self.wid);
        clos.handle.store(handle, Ordering::Relaxed);
        Ok(Value::I64(handle))
    }

    fn closure_store(&mut self, clos: Value, field: u32, value: Value) -> Result<()> {
        self.shared.registry.get(clos.as_i64()).fill(field, value);
        Ok(())
    }

    fn spawn_child(&mut self, callee: FuncId, args: &[Value], ret: KontRef) -> Result<()> {
        let cont = match ret {
            KontRef::Slot { clos, field } => {
                let c = self.shared.registry.get(clos.as_i64());
                c.hold();
                Cont::Slot { clos: c, slot: field }
            }
            KontRef::Counter { clos } => {
                let c = self.shared.registry.get(clos.as_i64());
                c.hold();
                Cont::Counter { clos: c }
            }
            KontRef::Forward => self.cont.clone(),
        };
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        if self.shared.kernels.kernel(callee).kind == FuncKind::Xla {
            // `args` is the spawner's kernel arg-staging slot slice: copy
            // it straight into the owned row the batch sink will consume
            // (no ArgList intermediary to convert at flush time). The row
            // is built before taking the queue lock so the allocation
            // never sits inside the shared critical section.
            let row = args.to_vec();
            self.shared.xla_queue.lock().unwrap().push((callee, row, cont));
            // Same idle gate as push_task: pay the futex only when a
            // worker actually sleeps.
            if self.shared.idle_workers.load(Ordering::Relaxed) > 0 {
                self.shared.idle_cv.notify_one();
            }
        } else {
            push_task(
                self.wid,
                self.shared,
                WsTask { task: callee, args: ArgList::from_slice(args), cont },
            );
        }
        Ok(())
    }

    fn close_spawns(&mut self, clos: Value) -> Result<()> {
        let c = self.shared.registry.get(clos.as_i64());
        if c.release() {
            fire(self.wid, self.shared, &c);
        }
        Ok(())
    }

    fn send_argument(&mut self, value: Value) -> Result<()> {
        deliver(self.wid, self.shared, self.cont.clone(), value)
    }
}
