//! Integration tests for the HardCilk backend: generated C++ sanity and
//! descriptor consistency across all workloads.

use bombyx::backend::hardcilk;
use bombyx::lower::{compile, CompileOptions};
use bombyx::util::json;
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

const ALL: &[(&str, &str)] = &[
    ("fib", fib::FIB_SRC),
    ("bfs", bfs::BFS_SRC),
    ("bfs_dae", bfs::BFS_DAE_SRC),
    ("nqueens", nqueens::NQUEENS_SRC),
    ("qsort", qsort::QSORT_SRC),
    ("relax", relax::RELAX_SRC),
];

#[test]
fn all_workloads_generate_hardcilk_systems() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        let sys = hardcilk::generate(&r.explicit, name).unwrap();
        assert!(!sys.pes.is_empty(), "{name}");
        // Every PE file mentions its stream protocol and no gotos.
        for (task, file, cpp) in &sys.pes {
            assert!(!cpp.contains("goto "), "{name}/{file}: Vitis rejects goto\n{cpp}");
            assert!(
                cpp.contains("task_in") || cpp.contains("BLACKBOX"),
                "{name}/{task}"
            );
        }
        // Descriptor parses back and task count matches PE count.
        let text = sys.descriptor.pretty();
        let parsed = json::parse(&text).unwrap();
        let tasks = parsed.get("tasks").unwrap().as_array().unwrap();
        assert_eq!(tasks.len(), sys.pes.len(), "{name}");
    }
}

#[test]
fn descriptor_spawn_edges_reference_existing_tasks() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        let sys = hardcilk::generate(&r.explicit, name).unwrap();
        let tasks = sys.descriptor.get("tasks").unwrap().as_array().unwrap().to_vec();
        let names: Vec<&str> =
            tasks.iter().filter_map(|t| t.get("name").unwrap().as_str()).collect();
        for t in &tasks {
            for list in ["spawns", "spawn_nexts", "send_argument_to"] {
                for target in t.get(list).unwrap().as_array().unwrap() {
                    let target = target.as_str().unwrap();
                    assert!(
                        names.contains(&target),
                        "{name}: `{}` {list} unknown task `{target}`",
                        t.get("name").unwrap().as_str().unwrap()
                    );
                }
            }
        }
    }
}

#[test]
fn closure_bits_in_descriptor_are_pow2() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        let sys = hardcilk::generate(&r.explicit, name).unwrap();
        for t in sys.descriptor.get("tasks").unwrap().as_array().unwrap() {
            let bits = t.get("closure_bits").unwrap().as_i64().unwrap();
            assert!((bits as u64).is_power_of_two(), "{name}: {bits}");
            let payload = t.get("closure_payload_bits").unwrap().as_i64().unwrap();
            assert!(payload <= bits, "{name}");
        }
    }
}

#[test]
fn generated_header_is_self_consistent() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let sys = hardcilk::generate(&r.explicit, "fib").unwrap();
    // Every closure struct referenced through a stream port exists in the
    // header.
    for (_, file, cpp) in &sys.pes {
        for line in cpp.lines() {
            if let Some(start) = line.find("hls::stream<closure_") {
                let rest = &line[start + "hls::stream<".len()..];
                let name: String = rest
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                assert!(
                    sys.header.contains(&format!("struct {name}")),
                    "{file}: missing struct {name}"
                );
            }
        }
    }
}

/// Round-trip tests for `backend/hardcilk/structurize`: walking the
/// structured tree with a deterministic branch oracle must visit exactly
/// the block sequence the raw CFG's successor edges produce — on every
/// diamond/loop shape the `.cilk` corpus lowers to (explicit tasks and
/// leaf functions of all six workloads).
mod structurize_roundtrip {
    use std::collections::HashMap;

    use bombyx::backend::hardcilk::structurize::{structurize, SNode};
    use bombyx::frontend::ast::UnOp;
    use bombyx::ir::cfg::{BlockId, Cfg, Term};
    use bombyx::ir::expr::Expr;
    use bombyx::lower::{compile, CompileOptions};

    use super::ALL;

    /// Deterministic branch oracle. Per-block visit counts are bounded so
    /// every data-dependent loop terminates regardless of shape.
    struct Oracle {
        seed: usize,
        counts: HashMap<usize, usize>,
    }

    impl Oracle {
        fn new(seed: usize) -> Oracle {
            Oracle { seed, counts: HashMap::new() }
        }

        fn decide(&mut self, b: BlockId) -> bool {
            let c = self.counts.entry(b.index()).or_insert(0);
            *c += 1;
            *c <= 3 && (*c + self.seed + b.index()) % 2 == 0
        }
    }

    /// Reference semantics: follow the CFG's successor edges.
    fn cfg_trace(cfg: &Cfg, oracle: &mut Oracle) -> Vec<usize> {
        let mut out = Vec::new();
        let mut cur = cfg.entry;
        loop {
            assert!(out.len() < 10_000, "runaway cfg trace");
            out.push(cur.index());
            match &cfg.blocks[cur].term {
                Term::Jump(t) => cur = *t,
                Term::Return(_) | Term::Halt => break,
                Term::Sync { .. } => unreachable!("explicit CFGs have no sync"),
                Term::Branch { then_, else_, .. } => {
                    cur = if oracle.decide(cur) { *then_ } else { *else_ };
                }
            }
        }
        out
    }

    /// Walk the structured tree with the same oracle. Returns true when
    /// the region ended at a terminating `Tail`.
    fn snode_trace(cfg: &Cfg, node: &SNode, oracle: &mut Oracle, out: &mut Vec<usize>) -> bool {
        match node {
            SNode::Ops(b) => {
                out.push(b.index());
                false
            }
            SNode::Tail(b) => {
                out.push(b.index());
                true
            }
            SNode::Seq(items) => {
                for item in items {
                    if snode_trace(cfg, item, oracle, out) {
                        return true;
                    }
                }
                false
            }
            SNode::If { cond_block, then_, else_, .. } => {
                if oracle.decide(*cond_block) {
                    snode_trace(cfg, then_, oracle, out)
                } else {
                    snode_trace(cfg, else_, oracle, out)
                }
            }
            SNode::While { header, cond, body } => {
                // The structurizer inverts the condition when the loop body
                // sits on the `else_` edge; detect that to interpret the
                // oracle's then/else decision identically on both sides.
                let Term::Branch { cond: cfg_cond, .. } = &cfg.blocks[*header].term else {
                    panic!("while header must end in a branch");
                };
                let inverted = format!("{cond:?}")
                    == format!("{:?}", Expr::Unary(UnOp::Not, Box::new(cfg_cond.clone())));
                loop {
                    assert!(out.len() < 10_000, "runaway snode trace");
                    out.push(header.index());
                    let take_then = oracle.decide(*header);
                    let enter_body = if inverted { !take_then } else { take_then };
                    if !enter_body {
                        break;
                    }
                    if snode_trace(cfg, body, oracle, out) {
                        return true;
                    }
                }
                false
            }
            SNode::Fsm(_) => panic!("corpus shapes must structurize without the FSM fallback"),
        }
    }

    fn count_fsm(n: &SNode) -> usize {
        match n {
            SNode::Fsm(_) => 1,
            SNode::Seq(items) => items.iter().map(count_fsm).sum(),
            SNode::If { then_, else_, .. } => count_fsm(then_) + count_fsm(else_),
            SNode::While { body, .. } => count_fsm(body),
            _ => 0,
        }
    }

    fn roundtrip_module(name: &str, src: &str, opts: &CompileOptions) -> usize {
        let r = compile(name, src, opts).unwrap();
        let mut checked = 0;
        for (_, f) in r.explicit.funcs.iter() {
            let Some(cfg) = f.body.as_ref() else { continue };
            let tree = structurize(cfg);
            if count_fsm(&tree) > 0 {
                // The switch-FSM fallback replays raw terminators, so its
                // successor semantics hold by construction; the round-trip
                // is only meaningful for reconstructed control flow.
                continue;
            }
            for seed in 0..6 {
                let mut cfg_oracle = Oracle::new(seed);
                let mut tree_oracle = Oracle::new(seed);
                let want = cfg_trace(cfg, &mut cfg_oracle);
                let mut got = Vec::new();
                snode_trace(cfg, &tree, &mut tree_oracle, &mut got);
                assert_eq!(
                    got, want,
                    "{name}/{}: seed {seed} diverged\ntree: {tree:?}",
                    f.name
                );
            }
            checked += 1;
        }
        checked
    }

    #[test]
    fn corpus_tasks_preserve_successor_semantics() {
        let mut total = 0;
        for (name, src) in ALL {
            total += roundtrip_module(name, src, &CompileOptions::standard());
        }
        // The DAE-off variants exercise the fused (loop + load) shapes.
        for (name, src) in ALL {
            total += roundtrip_module(name, src, &CompileOptions::no_dae());
        }
        assert!(total >= 10, "expected to round-trip many task CFGs, got {total}");
    }

    #[test]
    fn fib_and_bfs_structurize_without_fsm_fallback() {
        // The flagship shapes must reconstruct cleanly (pinned separately
        // from the sweep above, which skips FSM fallbacks).
        for (name, src) in [
            ("fib", bombyx::workloads::fib::FIB_SRC),
            ("bfs", bombyx::workloads::bfs::BFS_SRC),
        ] {
            let r = compile(name, src, &CompileOptions::no_dae()).unwrap();
            for (_, f) in r.explicit.funcs.iter() {
                let Some(cfg) = f.body.as_ref() else { continue };
                assert_eq!(count_fsm(&structurize(cfg)), 0, "{name}/{}", f.name);
            }
        }
    }

    #[test]
    fn diamond_and_loop_traces_take_both_sides() {
        // Sanity-check the oracle itself: over the seed range both branch
        // directions of fib's base-case diamond are exercised.
        let r = compile("fib", bombyx::workloads::fib::FIB_SRC, &CompileOptions::no_dae())
            .unwrap();
        let m = &r.explicit;
        let f = &m.funcs[m.func_by_name("fib").unwrap()];
        let cfg = f.cfg();
        let mut lens = std::collections::HashSet::new();
        for seed in 0..6 {
            let mut oracle = Oracle::new(seed);
            lens.insert(cfg_trace(cfg, &mut oracle).len());
        }
        assert!(lens.len() > 1, "oracle never flipped the entry branch: {lens:?}");
    }
}
