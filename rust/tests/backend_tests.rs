//! Integration tests for the HardCilk backend: generated C++ sanity and
//! descriptor consistency across all workloads.

use bombyx::backend::hardcilk;
use bombyx::lower::{compile, CompileOptions};
use bombyx::util::json;
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

const ALL: &[(&str, &str)] = &[
    ("fib", fib::FIB_SRC),
    ("bfs", bfs::BFS_SRC),
    ("bfs_dae", bfs::BFS_DAE_SRC),
    ("nqueens", nqueens::NQUEENS_SRC),
    ("qsort", qsort::QSORT_SRC),
    ("relax", relax::RELAX_SRC),
];

#[test]
fn all_workloads_generate_hardcilk_systems() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        let sys = hardcilk::generate(&r.explicit, name).unwrap();
        assert!(!sys.pes.is_empty(), "{name}");
        // Every PE file mentions its stream protocol and no gotos.
        for (task, file, cpp) in &sys.pes {
            assert!(!cpp.contains("goto "), "{name}/{file}: Vitis rejects goto\n{cpp}");
            assert!(
                cpp.contains("task_in") || cpp.contains("BLACKBOX"),
                "{name}/{task}"
            );
        }
        // Descriptor parses back and task count matches PE count.
        let text = sys.descriptor.pretty();
        let parsed = json::parse(&text).unwrap();
        let tasks = parsed.get("tasks").unwrap().as_array().unwrap();
        assert_eq!(tasks.len(), sys.pes.len(), "{name}");
    }
}

#[test]
fn descriptor_spawn_edges_reference_existing_tasks() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        let sys = hardcilk::generate(&r.explicit, name).unwrap();
        let tasks = sys.descriptor.get("tasks").unwrap().as_array().unwrap().to_vec();
        let names: Vec<&str> =
            tasks.iter().filter_map(|t| t.get("name").unwrap().as_str()).collect();
        for t in &tasks {
            for list in ["spawns", "spawn_nexts", "send_argument_to"] {
                for target in t.get(list).unwrap().as_array().unwrap() {
                    let target = target.as_str().unwrap();
                    assert!(
                        names.contains(&target),
                        "{name}: `{}` {list} unknown task `{target}`",
                        t.get("name").unwrap().as_str().unwrap()
                    );
                }
            }
        }
    }
}

#[test]
fn closure_bits_in_descriptor_are_pow2() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        let sys = hardcilk::generate(&r.explicit, name).unwrap();
        for t in sys.descriptor.get("tasks").unwrap().as_array().unwrap() {
            let bits = t.get("closure_bits").unwrap().as_i64().unwrap();
            assert!((bits as u64).is_power_of_two(), "{name}: {bits}");
            let payload = t.get("closure_payload_bits").unwrap().as_i64().unwrap();
            assert!(payload <= bits, "{name}");
        }
    }
}

#[test]
fn generated_header_is_self_consistent() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let sys = hardcilk::generate(&r.explicit, "fib").unwrap();
    // Every closure struct referenced through a stream port exists in the
    // header.
    for (_, file, cpp) in &sys.pes {
        for line in cpp.lines() {
            if let Some(start) = line.find("hls::stream<closure_") {
                let rest = &line[start + "hls::stream<".len()..];
                let name: String = rest
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                assert!(
                    sys.header.contains(&format!("struct {name}")),
                    "{file}: missing struct {name}"
                );
            }
        }
    }
}
