//! Integration tests for parallel batch compilation and incremental
//! per-function recompilation: determinism across thread counts,
//! byte-for-byte equality of spliced vs. cold-compiled modules, pass-work
//! accounting, and artifact invalidation.

use bombyx::ir::print::print_module;
use bombyx::lower::{
    compile_batch, pass_work, CompileOptions, CompileSession, RecompileMode,
};
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

fn corpus() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fib", fib::FIB_SRC),
        ("bfs", bfs::BFS_SRC),
        ("bfs_dae", bfs::BFS_DAE_SRC),
        ("nqueens", nqueens::NQUEENS_SRC),
        ("qsort", qsort::QSORT_SRC),
        ("relax", relax::RELAX_SRC),
    ]
}

/// Four functions so a one-function edit leaves three clean.
const FOUR_FUNCS: &str = "\
global int acc[4];
int leaf_a(int a) { return a * 3 + 1; }
int leaf_b(int a) { return a - 2; }
int work(int n) {
    if (n < 2) { int t = leaf_a(n); return t; }
    int x = cilk_spawn work(n - 1);
    int y = cilk_spawn work(n - 2);
    cilk_sync;
    int r = leaf_b(x + y);
    return r;
}
void top(int n) {
    int r = cilk_spawn work(n);
    cilk_sync;
    atomic_add(acc, 0, r);
}
";

// ---------------------------------------------------------------------------
// Batch determinism
// ---------------------------------------------------------------------------

#[test]
fn parallel_and_serial_batch_produce_identical_explicit_modules() {
    let corpus = corpus();
    let opts = CompileOptions::standard();
    let serial = compile_batch(&corpus, &opts, 1);
    let par = compile_batch(&corpus, &opts, 4);
    assert!(serial.errors().is_empty(), "{:?}", serial.errors());
    assert!(par.errors().is_empty(), "{:?}", par.errors());
    assert_eq!(serial.outcomes.len(), corpus.len());
    assert_eq!(par.outcomes.len(), corpus.len());
    for (i, (name, _)) in corpus.iter().enumerate() {
        // Input order is preserved regardless of sharding.
        assert_eq!(serial.outcomes[i].0, *name);
        assert_eq!(par.outcomes[i].0, *name);
        let s = serial.outcomes[i].1.as_ref().unwrap();
        let p = par.outcomes[i].1.as_ref().unwrap();
        assert_eq!(
            print_module(s.explicit()),
            print_module(p.explicit()),
            "explicit IR of `{name}` differs between jobs=1 and jobs=4"
        );
    }
}

#[test]
fn batch_merged_timings_cover_the_standard_pipeline() {
    let corpus = corpus();
    let batch = compile_batch(&corpus, &CompileOptions::standard(), 2);
    let names: Vec<&str> = batch.timings.iter().map(|t| t.pass).collect();
    for pass in ["ast_to_cfg", "simplify", "dae", "simplify_post_dae", "explicitize"] {
        assert!(names.contains(&pass), "merged timings missing `{pass}`: {names:?}");
    }
    // Function counts aggregate across the whole corpus.
    let ast = batch.timings.iter().find(|t| t.pass == "ast_to_cfg").unwrap();
    assert!(ast.funcs >= corpus.len(), "{:?}", batch.timings);
}

#[test]
fn batch_captures_per_source_errors_without_sinking_the_batch() {
    let sources = [
        ("good", fib::FIB_SRC),
        ("bad", "int nope("),
        ("also_good", qsort::QSORT_SRC),
    ];
    let batch = compile_batch(&sources, &CompileOptions::standard(), 3);
    assert_eq!(batch.sessions().len(), 2);
    let errors = batch.errors();
    assert_eq!(errors.len(), 1);
    assert_eq!(errors[0].0, "bad");
}

// ---------------------------------------------------------------------------
// Incremental recompilation
// ---------------------------------------------------------------------------

fn assert_matches_cold(session: &CompileSession, name: &str, edited: &str, opts: &CompileOptions) {
    let cold = CompileSession::new(name, edited, opts).unwrap();
    assert_eq!(
        print_module(session.implicit()),
        print_module(cold.implicit()),
        "implicit IR diverged from cold compile"
    );
    assert_eq!(
        print_module(session.implicit_dae()),
        print_module(cold.implicit_dae()),
        "post-DAE implicit IR diverged from cold compile"
    );
    assert_eq!(
        print_module(session.explicit()),
        print_module(cold.explicit()),
        "explicit IR diverged from cold compile"
    );
}

#[test]
fn one_function_edit_reruns_only_that_functions_passes() {
    let opts = CompileOptions::standard();
    let mut session = CompileSession::new("incr", FOUR_FUNCS, &opts).unwrap();
    let cold_work = pass_work(session.timings());
    let edited = FOUR_FUNCS.replace("a * 3 + 1", "a * 9 + 1");
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    assert_eq!(outcome.dirty, vec!["leaf_a".to_string()]);
    for t in &outcome.timings {
        if t.ran {
            assert_eq!(
                t.funcs, 1,
                "pass `{}` processed {} functions for a one-function edit",
                t.pass, t.funcs
            );
        }
    }
    let incr_work = pass_work(&outcome.timings);
    assert!(
        incr_work * 2 < cold_work,
        "incremental work {incr_work} must be < 50% of cold work {cold_work}"
    );
    assert_matches_cold(&session, "incr", &edited, &opts);
}

#[test]
fn incremental_splice_matches_cold_compile_for_dae_program() {
    let opts = CompileOptions::standard();
    let mut session = CompileSession::new("bfs_dae", bfs::BFS_DAE_SRC, &opts).unwrap();
    let edited = bfs::BFS_DAE_SRC.replace("visited[n] = 1", "visited[n] = 2");
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    assert_eq!(outcome.dirty, vec!["visit".to_string()]);
    assert_matches_cold(&session, "bfs_dae", &edited, &opts);
}

/// Two spawning tasks over two pre-declared globals. The tests insert
/// `#pragma bombyx dae` lines with [`with_dae`], so the set of access
/// functions the module needs changes between revisions while the
/// structural fingerprint (globals + signatures) stays put.
const TWO_GLOBALS: &str = "\
global int xs[];
global int ys[];

void scan_x(int n) {
    int v = xs[n];
    if (v > 0) {
        cilk_spawn scan_x(n - 1);
    }
    cilk_sync;
}
void scan_y(int n) {
    int v = ys[n];
    if (v > 0) {
        cilk_spawn scan_y(n - 1);
    }
    cilk_sync;
}
void run(int n) {
    cilk_spawn scan_x(n);
    cilk_spawn scan_y(n);
    cilk_sync;
}
";

/// Annotate the (unique) statement `load` with the DAE pragma.
fn with_dae(src: &str, load: &str) -> String {
    let out = src.replace(load, &format!("#pragma bombyx dae\n    {load}"));
    assert_ne!(out, src, "load statement `{load}` not found");
    out
}

#[test]
fn edit_adding_first_dae_load_of_new_global_splices_incrementally() {
    // The edit makes dirty `scan_y` carry the module's first DAE load of
    // `ys`: a cold compile appends a brand-new `ys_access` function, so
    // the access-func id space grows. This used to force a full
    // recompile; the id-remapping splice must keep it incremental.
    let opts = CompileOptions::standard();
    let base = with_dae(TWO_GLOBALS, "int v = xs[n];");
    let mut session = CompileSession::new("two_globals", &base, &opts).unwrap();
    let edited = with_dae(&base, "int v = ys[n];");
    let cold_work = pass_work(CompileSession::new("two_globals", &edited, &opts).unwrap().timings());
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    assert_eq!(outcome.dirty, vec!["scan_y".to_string()]);
    let incr_work = pass_work(&outcome.timings);
    assert!(
        incr_work < cold_work,
        "incremental work {incr_work} must be below cold work {cold_work}"
    );
    assert_matches_cold(&session, "two_globals", &edited, &opts);
}

#[test]
fn edit_removing_last_dae_load_splices_incrementally() {
    // Dropping the only pragma empties the needed access-func set: the
    // cached post-DAE module has an access function a cold compile of
    // the edited source would not, so the stale id (and its partition
    // entry) must disappear without a full recompile.
    let opts = CompileOptions::standard();
    let base = with_dae(TWO_GLOBALS, "int v = xs[n];");
    let mut session = CompileSession::new("two_globals", &base, &opts).unwrap();
    let outcome = session.recompile(TWO_GLOBALS).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    assert_eq!(outcome.dirty, vec!["scan_x".to_string()]);
    assert_matches_cold(&session, "two_globals", TWO_GLOBALS, &opts);
}

#[test]
fn clean_function_access_calls_are_remapped_when_ids_shift() {
    // Base has DAE only on `ys`, so `ys_access` sits at the first
    // post-source id. The edit adds a DAE load of `xs` in `scan_x`;
    // cold creation order puts `xs_access` first, shifting `ys_access`
    // up by one — and *clean* `scan_y` still spawns it, so its call
    // sites must be remapped to the new id.
    let opts = CompileOptions::standard();
    let base = with_dae(TWO_GLOBALS, "int v = ys[n];");
    let mut session = CompileSession::new("two_globals", &base, &opts).unwrap();
    let edited = with_dae(&base, "int v = xs[n];");
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    assert_eq!(outcome.dirty, vec!["scan_x".to_string()]);
    assert_matches_cold(&session, "two_globals", &edited, &opts);
}

#[test]
fn task_structure_edit_still_matches_cold_compile() {
    // Adding a sync changes `work`'s path partition (more continuation
    // tasks), which shifts explicit FuncIds — the splicer must detect the
    // layout change and re-convert, still producing the cold-compile
    // module exactly.
    let opts = CompileOptions::standard();
    let mut session = CompileSession::new("incr", FOUR_FUNCS, &opts).unwrap();
    let edited = FOUR_FUNCS.replace(
        "int y = cilk_spawn work(n - 2);\n    cilk_sync;",
        "cilk_sync;\n    int y = cilk_spawn work(n - 2);\n    cilk_sync;",
    );
    assert_ne!(edited, FOUR_FUNCS, "test edit must apply");
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    assert_eq!(outcome.dirty, vec!["work".to_string()]);
    assert_matches_cold(&session, "incr", &edited, &opts);
}

#[test]
fn structural_edit_falls_back_to_full_recompile_and_matches_cold() {
    let opts = CompileOptions::standard();
    let mut session = CompileSession::new("incr", FOUR_FUNCS, &opts).unwrap();
    // A new function changes the signature structure: incremental
    // splicing is unsound, the driver must run the full pipeline.
    let edited = format!("{FOUR_FUNCS}\nint extra(int q) {{ return q + 40; }}\n");
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Full);
    assert_matches_cold(&session, "incr", &edited, &opts);
}

#[test]
fn whitespace_only_edit_is_unchanged_and_keeps_artifacts() {
    let opts = CompileOptions::no_dae();
    let mut session = CompileSession::new("fib", fib::FIB_SRC, &opts).unwrap();
    let emu_before: *const bombyx::backend::emu::EmuProgram = session.emu_program();
    let _ = session.rtl_system("fib_system").unwrap();
    let timings_before = session.timings().len();

    // Shift every span; no fingerprint may change.
    let shifted = format!("\n\n  {}", fib::FIB_SRC);
    let outcome = session.recompile(&shifted).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Unchanged);
    assert!(outcome.dirty.is_empty());
    assert_eq!(pass_work(&outcome.timings), 0, "unchanged source must do zero pass work");

    // Memoized artifacts survive: same emu allocation, cached rtl system
    // returned with no new emission pass recorded.
    let emu_after: *const bombyx::backend::emu::EmuProgram = session.emu_program();
    assert_eq!(emu_before, emu_after);
    let _ = session.rtl_system("fib_system").unwrap();
    assert_eq!(session.timings().len(), timings_before, "rtl must come from the cache");
}

#[test]
fn real_edit_invalidates_dependent_artifacts() {
    let opts = CompileOptions::standard();
    let mut session = CompileSession::new("incr", FOUR_FUNCS, &opts).unwrap();
    let _ = session.rtl_system("sys").unwrap();
    let _ = session.hardcilk_system("sys").unwrap();
    let with_rtl = session.timings().len();
    assert!(with_rtl > 5, "rtl emission must be a timed pass");

    let edited = FOUR_FUNCS.replace("a - 2", "a - 7");
    let outcome = session.recompile(&edited).unwrap();
    assert_eq!(outcome.mode, RecompileMode::Incremental);
    // The timings now describe the recompile only (no stale rtl row)...
    assert_eq!(session.timings().len(), 5);
    // ...and requesting the system again re-emits against the new module.
    let _ = session.rtl_system("sys").unwrap();
    assert_eq!(session.timings().len(), 6);
}

#[test]
fn second_rtl_emission_does_zero_lowering_work() {
    let mut session =
        CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let sys1: *const bombyx::backend::rtl::RtlSystem = session.rtl_system("fib_system").unwrap();
    let after_first = session.timings().len();
    let sys2: *const bombyx::backend::rtl::RtlSystem = session.rtl_system("fib_system").unwrap();
    assert_eq!(sys1, sys2, "second request must return the cached system");
    assert_eq!(
        session.timings().len(),
        after_first,
        "second rtl_system call must record no new pass (zero lowering/emission work)"
    );
}

// ---------------------------------------------------------------------------
// Acceptance: all four compile routes agree on every corpus program
// ---------------------------------------------------------------------------

#[test]
fn cold_batch_serial_batch_parallel_and_incremental_agree_on_corpus() {
    let corpus = corpus();
    let opts = CompileOptions::standard();
    let serial = compile_batch(&corpus, &opts, 1);
    let par = compile_batch(&corpus, &opts, 4);
    for (i, (name, src)) in corpus.iter().enumerate() {
        let cold = CompileSession::new(name, src, &opts).unwrap();
        let want = print_module(cold.explicit());

        let s = serial.outcomes[i].1.as_ref().unwrap();
        assert_eq!(print_module(s.explicit()), want, "serial batch differs on `{name}`");
        let p = par.outcomes[i].1.as_ref().unwrap();
        assert_eq!(print_module(p.explicit()), want, "parallel batch differs on `{name}`");

        // Incremental route: start from a whitespace-shifted variant
        // (same fingerprints), then recompile to the original text.
        let mut incr = CompileSession::new(name, &format!("\n{src}"), &opts).unwrap();
        let outcome = incr.recompile(src).unwrap();
        assert_eq!(outcome.mode, RecompileMode::Unchanged, "{name}");
        assert_eq!(print_module(incr.explicit()), want, "incremental differs on `{name}`");
    }
}
