//! End-to-end tests over the full three-layer stack: compiled Cilk-C with
//! an `extern xla` datapath, executed with the AOT Pallas/XLA artifact on
//! the simulator and the WS runtime. Skipped (with a notice) when
//! artifacts are not built.

use bombyx::coordinator::RelaxExperiment;
use bombyx::ir::Value;
use bombyx::lower::{compile, CompileOptions};
use bombyx::runtime::{RelaxService, XlaRuntime};
use bombyx::sim::SimConfig;
use bombyx::workloads::{graphgen, relax};
use bombyx::ws::{self, WsConfig, XlaSink};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn have_artifacts() -> bool {
    XlaRuntime::load_dir(artifacts_dir()).is_ok()
}

#[test]
fn relax_sim_xla_matches_scalar_end_to_end() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let graph = graphgen::tree(3, 5); // 121 nodes
    let cfg = SimConfig::default();
    let runtime = XlaRuntime::load_dir(artifacts_dir()).unwrap();
    // One compile session serves both datapaths.
    let exp = RelaxExperiment::new().unwrap();
    let xla = exp.run_sim(runtime, &graph, 7, &cfg).unwrap();
    let scalar = exp.run_scalar(&graph, 7, &cfg).unwrap();
    assert_eq!(xla.nodes_expanded, scalar.nodes_expanded);
    let rel = (xla.feat_checksum - scalar.feat_checksum).abs()
        / scalar.feat_checksum.abs().max(1e-9);
    assert!(rel < 1e-3, "checksum drift {rel}");
    assert!(xla.xla_batches >= 1);
}

#[test]
fn relax_ws_runtime_with_service_thread() {
    if !have_artifacts() {
        return;
    }
    let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.explicit;
    let graph = graphgen::tree(4, 4); // 85 nodes
    let mut seq = bombyx::interp::Memory::new(m);
    relax::init_memory(m, &mut seq, &graph, 5).unwrap();
    let mem = bombyx::backend::emu::shared_from(m, &seq);
    let svc = RelaxService::start(artifacts_dir(), m, 5).unwrap();
    let cfg = WsConfig { workers: 4, steal_tries: 4 };
    let (v, mem, stats) =
        ws::run(m, mem, "expand", &[Value::I64(0)], &cfg, Box::new(svc)).unwrap();
    assert_eq!(v, Value::Unit);
    assert!(stats.xla_tasks >= 1, "xla tasks batched: {stats:?}");
    let work = mem.dump_i64(m.global_by_name("work_done").unwrap())[0];
    assert!(work >= 1, "at least the root must be expanded");
    // Every visited node did exactly one relax.
    let visited: i64 = mem.dump_i64(m.global_by_name("visited").unwrap()).iter().sum();
    assert_eq!(work, visited);
}

#[test]
fn relax_service_rejects_unknown_task() {
    if !have_artifacts() {
        return;
    }
    let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
    let svc = RelaxService::start(artifacts_dir(), &r.explicit, 1).unwrap();
    let mem = ws::SharedMemory::new(&r.explicit);
    let err = svc.exec_batch("other", &[vec![Value::I64(0)]], &mem).unwrap_err();
    assert!(err.to_string().contains("only implements"));
}

#[test]
fn headline_quickstart_binary_paths_compile() {
    // Compile the on-disk example programs end to end (covers the repo's
    // examples/cilk/*.cilk against the library API the examples use).
    for file in ["fib.cilk", "bfs.cilk", "bfs_dae.cilk", "nqueens.cilk", "relax.cilk"] {
        let path =
            std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/examples/cilk")).join(file);
        let src = std::fs::read_to_string(&path).unwrap();
        compile(file, &src, &CompileOptions::standard())
            .unwrap_or_else(|e| panic!("{file}: {e:#}"));
    }
}
