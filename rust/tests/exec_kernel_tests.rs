//! Differential tests for the shared execution-kernel layer.
//!
//! Every engine in the repo now runs the same compiled register bytecode
//! (`bombyx::exec`). The independent baseline here is a *tree-walking*
//! reference oracle kept inside this test (recursive serial elision over
//! the implicit IR via `ir::expr::eval` — the pre-kernel executor
//! semantics, frozen). For all seven corpus workloads, under both DAE
//! variants, every kernel engine must produce the reference's result and
//! memory image, and the deterministic task/closure counters must agree
//! across the explicit machine, the WS runtime (1 and 4 workers) and the
//! simulator.

use std::sync::Arc;

use anyhow::Result;
use bombyx::backend::emu;
use bombyx::exec::{compile_module, compile_module_with, ArgList, KStack, KernelMode, KernelProgram};
use bombyx::interp::explicit_exec::ExplicitExec;
use bombyx::interp::{FnXla, Memory, NoXla};
use bombyx::ir::cfg::{FuncKind, Module, Op, Term};
use bombyx::ir::expr::{eval, Value, VarId};
use bombyx::ir::{FuncId, GlobalId};
use bombyx::lower::{compile, CompileOptions, CompileResult};
use bombyx::sim::exec::{trace_task, Effect, FnState, SCont, STask, Seg};
use bombyx::sim::{simulate, simulate_with_kernels, NoSimXla, SimConfig, SimXla};
use bombyx::util::golden::check_golden;
use bombyx::workloads::{bfs, fib, graphgen, nqueens, qsort, relax, rmw};
use bombyx::ws::{self, NoXlaSink, ScalarSink, SharedMemory, WsConfig};

// ---------------------------------------------------------------------------
// Frozen tree-walking reference (pre-kernel oracle semantics)

type TreeXla<'a> = &'a mut dyn FnMut(&[Value], &mut Memory) -> Result<Value>;

fn tree_call(
    m: &Module,
    fid: FuncId,
    args: &[Value],
    mem: &mut Memory,
    xla: TreeXla<'_>,
) -> Result<Value> {
    let func = &m.funcs[fid];
    if func.kind == FuncKind::Xla {
        return xla(args, mem);
    }
    let cfg = func.body.as_ref().expect("implicit function has a body");
    let mut env: Vec<Value> = func.vars.values().map(|v| Value::zero_of(v.ty)).collect();
    for (i, a) in args.iter().enumerate() {
        env[i] = a.coerce(func.vars[VarId::new(i)].ty);
    }
    let mut block = cfg.entry;
    loop {
        let b = &cfg.blocks[block];
        for op in &b.ops {
            match op {
                Op::Assign { dst, src } => {
                    let v = eval(src, &|v| env[v.index()]);
                    env[dst.index()] = v.coerce(func.vars[*dst].ty);
                }
                Op::Load { dst, arr, index, .. } => {
                    let idx = eval(index, &|v| env[v.index()]).as_i64();
                    env[dst.index()] = mem.load(*arr, idx)?;
                }
                Op::Store { arr, index, value } => {
                    let idx = eval(index, &|v| env[v.index()]).as_i64();
                    let val = eval(value, &|v| env[v.index()]);
                    mem.store(*arr, idx, val)?;
                }
                Op::AtomicAdd { arr, index, value } => {
                    let idx = eval(index, &|v| env[v.index()]).as_i64();
                    let val = eval(value, &|v| env[v.index()]);
                    mem.atomic_add(*arr, idx, val)?;
                }
                Op::Call { dst, callee, args } | Op::Spawn { dst, callee, args } => {
                    let vals: Vec<Value> =
                        args.iter().map(|a| eval(a, &|v| env[v.index()])).collect();
                    let r = tree_call(m, *callee, &vals, mem, xla)?;
                    if let Some(d) = dst {
                        env[d.index()] = r.coerce(func.vars[*d].ty);
                    }
                }
                other => anyhow::bail!("tree reference: unexpected implicit op {other:?}"),
            }
        }
        match &b.term {
            Term::Jump(n) | Term::Sync { next: n } => block = *n,
            Term::Branch { cond, then_, else_ } => {
                block = if eval(cond, &|v| env[v.index()]).as_bool() { *then_ } else { *else_ };
            }
            Term::Return(v) => {
                return Ok(match v {
                    Some(e) => eval(e, &|v| env[v.index()]).coerce(func.ret),
                    None => Value::Unit,
                });
            }
            Term::Halt => anyhow::bail!("tree reference runs implicit IR only"),
        }
    }
}

// ---------------------------------------------------------------------------
// Relax scalar datapath adapters (one per engine interface)

fn relax_row(
    n: usize,
    read: &mut dyn FnMut(i64) -> Result<Value>,
    write: &mut dyn FnMut(i64, Value) -> Result<()>,
    w: &[f32],
    b: &[f32],
) -> Result<Value> {
    let f = relax::F;
    let x: Vec<f32> = (0..f)
        .map(|j| read((n * f + j) as i64).map(|v| v.as_f32()))
        .collect::<Result<_>>()?;
    let (y, score) = relax::relax_ref(&x, w, b);
    for (j, &v) in y.iter().enumerate() {
        write((n * f + j) as i64, Value::F32(v))?;
    }
    Ok(Value::I64((score * 1000.0) as i64))
}

struct SimScalarRelax {
    w: Vec<f32>,
    b: Vec<f32>,
    feat: GlobalId,
}

impl SimXla for SimScalarRelax {
    fn exec_batch(
        &mut self,
        _name: &str,
        batch: &[Vec<Value>],
        memory: &mut Memory,
    ) -> Result<Vec<Value>> {
        let feat = self.feat;
        batch
            .iter()
            .map(|args| {
                let n = args[0].as_i64() as usize;
                relax_row(
                    n,
                    &mut |i| memory.load(feat, i),
                    &mut |i, v| memory.store(feat, i, v),
                    &self.w,
                    &self.b,
                )
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// The harness

const RELAX_SEED: u64 = 5;

/// Deterministic per-engine counters compared across engines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Counters {
    tasks: u64,
    closures: u64,
}

struct Workload {
    name: &'static str,
    src: &'static str,
    entry: &'static str,
    args: Vec<Value>,
    init: Box<dyn Fn(&Module, &mut Memory)>,
    uses_xla: bool,
}

fn corpus() -> Vec<Workload> {
    let bfs_graph = graphgen::tree(3, 4); // 121 nodes
    let bfs_graph2 = graphgen::tree(3, 4);
    let relax_graph = graphgen::tree(3, 3); // 40 nodes
    let qsort_input: Vec<i64> = (0..48).map(|i| ((i * 37 + 11) % 100) - 50).collect();
    vec![
        Workload {
            name: "fib",
            src: fib::FIB_SRC,
            entry: "fib",
            args: vec![Value::I64(12)],
            init: Box::new(|_, _| {}),
            uses_xla: false,
        },
        Workload {
            name: "bfs",
            src: bfs::BFS_SRC,
            entry: "visit",
            args: vec![Value::I64(0)],
            init: Box::new(move |m, mem| bfs::init_memory(m, mem, &bfs_graph).unwrap()),
            uses_xla: false,
        },
        Workload {
            name: "bfs_dae",
            src: bfs::BFS_DAE_SRC,
            entry: "visit",
            args: vec![Value::I64(0)],
            init: Box::new(move |m, mem| bfs::init_memory(m, mem, &bfs_graph2).unwrap()),
            uses_xla: false,
        },
        Workload {
            name: "nqueens",
            src: nqueens::NQUEENS_SRC,
            entry: "place",
            args: [6i64, 0, 0, 0, 0].iter().map(|&v| Value::I64(v)).collect(),
            init: Box::new(|_, _| {}),
            uses_xla: false,
        },
        Workload {
            name: "qsort",
            src: qsort::QSORT_SRC,
            entry: "qsort_",
            args: vec![Value::I64(0), Value::I64(47)],
            init: Box::new(move |m, mem| {
                mem.fill_i64(m.global_by_name("data").unwrap(), &qsort_input);
            }),
            uses_xla: false,
        },
        Workload {
            name: "relax",
            src: relax::RELAX_SRC,
            entry: "expand",
            args: vec![Value::I64(0)],
            init: Box::new(move |m, mem| {
                relax::init_memory(m, mem, &relax_graph, RELAX_SEED).unwrap()
            }),
            uses_xla: true,
        },
        // Exercises the widened fusion peepholes (load→bin→store
        // triples, bin→atomic_add, bin→send_argument).
        Workload {
            name: "rmw",
            src: rmw::RMW_SRC,
            entry: "bump",
            args: vec![Value::I64(0), Value::I64(rmw::N as i64)],
            init: Box::new(|m, mem| rmw::init_memory(m, mem).unwrap()),
            uses_xla: false,
        },
    ]
}

/// Dump every global of `module` (floats exactly, ints exactly), keyed by
/// name so images compare across the implicit/explicit modules.
fn memory_image(module: &Module, mem: &Memory) -> Vec<(String, Vec<i64>, Vec<u32>)> {
    module
        .globals
        .iter()
        .map(|(gid, g)| {
            let ints = mem.dump_i64(gid);
            let floats = mem.dump_f32(gid).iter().map(|f| f.to_bits()).collect();
            (g.name.clone(), ints, floats)
        })
        .collect()
}

fn shared_memory_image(module: &Module, mem: &SharedMemory) -> Vec<(String, Vec<i64>, Vec<u32>)> {
    module
        .globals
        .iter()
        .map(|(gid, g)| {
            let ints = mem.dump_i64(gid);
            let floats = mem.dump_f32(gid).iter().map(|f| f.to_bits()).collect();
            (g.name.clone(), ints, floats)
        })
        .collect()
}

fn fn_xla_for(module: &Module) -> FnXla {
    let mut handler = FnXla::default();
    let feat = module.global_by_name("feat").expect("relax module has feat");
    let (w, b) = relax::weights(RELAX_SEED);
    handler.register("relax", move |args: &[Value], mem: &mut Memory| {
        let n = args[0].as_i64() as usize;
        relax_row(n, &mut |i| mem.load(feat, i), &mut |i, v| mem.store(feat, i, v), &w, &b)
    });
    handler
}

fn check_workload(w: &Workload, opts: &CompileOptions, r: &CompileResult) {
    let label = format!("{} ({:?})", w.name, opts.dae);

    // 1. Frozen tree-walking reference on the implicit IR.
    let (ref_val, ref_image) = {
        let m = &r.implicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let fid = m.func_by_name(w.entry).unwrap();
        let (w2, b2) = relax::weights(RELAX_SEED);
        let feat = m.global_by_name("feat");
        let mut xla = move |args: &[Value], mem: &mut Memory| {
            let n = args[0].as_i64() as usize;
            let feat = feat.expect("xla workload has feat");
            relax_row(n, &mut |i| mem.load(feat, i), &mut |i, v| mem.store(feat, i, v), &w2, &b2)
        };
        let v = tree_call(m, fid, &w.args, &mut mem, &mut xla).expect("tree reference");
        (v.as_i64(), memory_image(m, &mem))
    };

    // 2. Kernel oracle on the implicit IR.
    {
        let m = &r.implicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let xla = if w.uses_xla { fn_xla_for(m) } else { FnXla::default() };
        let mut o = bombyx::interp::oracle::Oracle::new(m, mem, xla);
        let v = o.run(w.entry, &w.args).expect("kernel oracle");
        assert_eq!(v.as_i64(), ref_val, "{label}: oracle value");
        assert_eq!(memory_image(m, &o.memory), ref_image, "{label}: oracle memory");
    }

    // 3. Explicit machine on the explicit IR.
    let explicit_counters = {
        let m = &r.explicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let xla = if w.uses_xla { fn_xla_for(m) } else { FnXla::default() };
        let mut ex = ExplicitExec::new(m, mem, xla);
        let v = ex.run(w.entry, &w.args).expect("explicit machine");
        assert_eq!(v.as_i64(), ref_val, "{label}: explicit value");
        assert_eq!(ex.live_closures(), 0, "{label}: explicit closure leak");
        assert_eq!(memory_image(m, &ex.memory), ref_image, "{label}: explicit memory");
        Counters { tasks: ex.stats.tasks_run, closures: ex.stats.closures_made }
    };

    // 4. WS runtime, 1 and 4 workers.
    let mut ws_counters = Vec::new();
    let mut ws_xla_tasks = 0;
    for workers in [1usize, 4] {
        let m = &r.explicit;
        let mut seed = Memory::new(m);
        (w.init)(m, &mut seed);
        let mem = emu::shared_from(m, &seed);
        let cfg = WsConfig { workers, steal_tries: 4 };
        let (w2, b2) = relax::weights(RELAX_SEED);
        let feat = m.global_by_name("feat");
        let (v, mem, stats) = if w.uses_xla {
            let sink = ScalarSink(move |_n: &str, args: &[Value], mem: &SharedMemory| {
                let n = args[0].as_i64() as usize;
                let feat = feat.expect("feat");
                relax_row(
                    n,
                    &mut |i| mem.load(feat, i),
                    &mut |i, v| mem.store(feat, i, v),
                    &w2,
                    &b2,
                )
            });
            ws::run(m, mem, w.entry, &w.args, &cfg, Box::new(sink)).expect("ws run")
        } else {
            ws::run(m, mem, w.entry, &w.args, &cfg, Box::new(NoXlaSink)).expect("ws run")
        };
        assert_eq!(v.as_i64(), ref_val, "{label}: ws value (workers={workers})");
        assert_eq!(
            shared_memory_image(m, &mem),
            ref_image,
            "{label}: ws memory (workers={workers})"
        );
        if workers == 1 {
            assert_eq!(stats.steals, 0, "{label}: single worker cannot steal");
        }
        ws_xla_tasks = stats.xla_tasks;
        ws_counters.push(Counters { tasks: stats.tasks_run, closures: stats.closures_made });
    }

    // 5. Simulator.
    let sim_counters = {
        let m = &r.explicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let cfg = SimConfig::default();
        let (v, mem, stats) = if w.uses_xla {
            let (w2, b2) = relax::weights(RELAX_SEED);
            let mut xla = SimScalarRelax {
                w: w2,
                b: b2,
                feat: m.global_by_name("feat").unwrap(),
            };
            simulate(m, mem, w.entry, &w.args, &cfg, &mut xla).expect("sim")
        } else {
            simulate(m, mem, w.entry, &w.args, &cfg, &mut NoSimXla).expect("sim")
        };
        assert_eq!(v.as_i64(), ref_val, "{label}: sim value");
        assert_eq!(memory_image(m, &mem), ref_image, "{label}: sim memory");
        Counters { tasks: stats.tasks_run, closures: stats.closures_made }
    };

    // 6. Deterministic counters agree across engines. The explicit
    // machine counts xla instances in tasks_run; the WS runtime and the
    // simulator account for them separately (batch paths).
    assert_eq!(
        ws_counters[0], ws_counters[1],
        "{label}: ws counters deterministic across worker counts"
    );
    if w.uses_xla {
        assert_eq!(
            explicit_counters.tasks,
            ws_counters[0].tasks + ws_xla_tasks,
            "{label}: explicit vs ws task accounting"
        );
        assert_eq!(
            explicit_counters.tasks,
            sim_counters.tasks + ws_xla_tasks,
            "{label}: explicit vs sim task accounting"
        );
    } else {
        assert_eq!(explicit_counters.tasks, ws_counters[0].tasks, "{label}: tasks explicit/ws");
        assert_eq!(explicit_counters.tasks, sim_counters.tasks, "{label}: tasks explicit/sim");
    }
    assert_eq!(
        explicit_counters.closures, ws_counters[0].closures,
        "{label}: closures explicit/ws"
    );
    assert_eq!(
        explicit_counters.closures, sim_counters.closures,
        "{label}: closures explicit/sim"
    );
}

#[test]
fn all_corpus_workloads_agree_across_engines_no_dae() {
    let opts = CompileOptions::no_dae();
    for w in corpus() {
        let r = compile(w.name, w.src, &opts).unwrap();
        check_workload(&w, &opts, &r);
    }
}

#[test]
fn all_corpus_workloads_agree_across_engines_dae() {
    let opts = CompileOptions::standard();
    for w in corpus() {
        let r = compile(w.name, w.src, &opts).unwrap();
        check_workload(&w, &opts, &r);
    }
}

#[test]
fn fib_counters_match_pre_kernel_oracle_pins() {
    // Pinned against the tree-walking engines before the kernel rework:
    // fib(10) = 177 entry tasks + 88 continuations = 265 task instances
    // and 88 closures, on every engine.
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.explicit;

    let mut ex = ExplicitExec::new(m, Memory::new(m), NoXla);
    let v = ex.run("fib", &[Value::I64(10)]).unwrap();
    assert_eq!(v.as_i64(), 55);
    assert_eq!(ex.stats.tasks_run, 265);
    assert_eq!(ex.stats.closures_made, 88);

    let cfg = WsConfig { workers: 2, steal_tries: 4 };
    let (v, _, stats) = ws::run(
        m,
        SharedMemory::new(m),
        "fib",
        &[Value::I64(10)],
        &cfg,
        Box::new(NoXlaSink),
    )
    .unwrap();
    assert_eq!(v.as_i64(), 55);
    assert_eq!(stats.tasks_run, 265);
    assert_eq!(stats.closures_made, 88);
    assert!(stats.max_live_closures >= 1 && stats.max_live_closures <= 88);

    let (v, _, stats) = simulate(
        m,
        Memory::new(m),
        "fib",
        &[Value::I64(10)],
        &SimConfig::default(),
        &mut NoSimXla,
    )
    .unwrap();
    assert_eq!(v.as_i64(), 55);
    assert_eq!(stats.tasks_run, 265);
    assert_eq!(stats.closures_made, 88);
}

#[test]
fn fib_kernel_disassembly_golden() {
    // The compiled explicit-mode bytecode for fib, pinned as a golden:
    // operand slots, folded immediates, resolved branch targets and
    // per-op cost annotations are all visible in the listing.
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let prog = compile_module(&r.explicit, KernelMode::Explicit).unwrap();
    check_golden("rust/tests/goldens/kernels/fib_explicit.disasm", &prog.disasm());
}

#[test]
fn session_caches_one_kernel_program_for_all_engines() {
    use bombyx::lower::CompileSession;
    let session =
        CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let k1 = session.explicit_kernels().unwrap();
    let k2 = session.explicit_kernels().unwrap();
    assert!(std::sync::Arc::ptr_eq(&k1, &k2), "kernel program memoized");
    // All engine entry points run on it.
    let (v, _) = session.run_explicit(session.memory(), "fib", &[Value::I64(10)]).unwrap();
    assert_eq!(v.as_i64(), 55);
    let (v, _) = session.run_oracle(session.implicit_memory(), "fib", &[Value::I64(10)]).unwrap();
    assert_eq!(v.as_i64(), 55);
    let cfg = WsConfig { workers: 2, steal_tries: 2 };
    let (v, _, _) = session
        .run_ws(session.shared_memory(), "fib", &[Value::I64(10)], &cfg, Box::new(NoXlaSink))
        .unwrap();
    assert_eq!(v.as_i64(), 55);
    let (v, _, _) = session
        .simulate(session.memory(), "fib", &[Value::I64(10)], &SimConfig::default(), &mut NoSimXla)
        .unwrap();
    assert_eq!(v.as_i64(), 55);
}

// ---------------------------------------------------------------------------
// Superinstruction fusion: on-vs-off differential across all engines

fn kernels_pair(module: &Module, mode: KernelMode) -> (Arc<KernelProgram>, Arc<KernelProgram>) {
    let fused = compile_module_with(module, mode, true).expect("fused compile");
    let unfused = compile_module_with(module, mode, false).expect("unfused compile");
    (Arc::new(fused), Arc::new(unfused))
}

/// Replay a program's task graph dispatch-by-dispatch through the
/// simulator's functional tracer, returning each dispatch's timed trace
/// as its (byte-exact) debug rendering. Non-xla workloads only.
fn collect_traces(
    prog: &Arc<KernelProgram>,
    module: &Module,
    w: &Workload,
    limit: usize,
) -> Vec<String> {
    let model = bombyx::hls::ScheduleModel::default();
    let mut mem = Memory::new(module);
    (w.init)(module, &mut mem);
    let mut state =
        FnState { memory: mem, closures: Vec::new(), live_closures: 0, closures_made: 0 };
    let fid = prog.func_by_name(w.entry).expect("entry kernel");
    let mut stack = KStack::new();
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(STask { task: fid, args: ArgList::from_slice(&w.args), cont: SCont::Root });

    fn fire_on_zero(
        state: &mut FnState,
        queue: &mut std::collections::VecDeque<STask>,
        clos: usize,
    ) {
        {
            let c = &mut state.closures[clos];
            c.counter -= 1;
            if c.counter != 0 {
                return;
            }
            c.freed = true;
        }
        state.live_closures -= 1;
        let (task, args, cont) = {
            let c = &state.closures[clos];
            (c.task, ArgList::from_slice(&c.slots), c.cont)
        };
        queue.push_back(STask { task, args, cont });
    }

    let mut out = Vec::new();
    while let Some(task) = queue.pop_front() {
        if out.len() >= limit {
            break;
        }
        let mut trace: Vec<Seg> = Vec::new();
        trace_task(prog, &model, &mut state, &task, &mut stack, &mut trace).expect("trace_task");
        out.push(format!("{trace:?}"));
        for seg in trace {
            let Seg::Effect(e) = seg else { continue };
            match e {
                Effect::Spawn(t) => queue.push_back(t),
                Effect::ClosureStore { clos, slot, value } => {
                    let ty = prog.kernel(state.closures[clos].task).param_tys[slot as usize];
                    state.closures[clos].slots[slot as usize] = value.coerce(ty);
                }
                Effect::FillDecrement { clos, slot, value } => {
                    let ty = prog.kernel(state.closures[clos].task).param_tys[slot as usize];
                    state.closures[clos].slots[slot as usize] = value.coerce(ty);
                    fire_on_zero(&mut state, &mut queue, clos);
                }
                Effect::Decrement { clos } => fire_on_zero(&mut state, &mut queue, clos),
                Effect::RootResult(_) => {}
            }
        }
    }
    out
}

/// Run workload `w` on every engine twice — once on fused kernels, once
/// on unfused — and require identical values, memory images,
/// deterministic counters and (for the simulator) identical cycle
/// figures plus byte-identical per-dispatch traces.
fn check_fusion_differential(w: &Workload, r: &CompileResult, label: &str) {
    // Oracle over implicit kernels.
    let (ion, ioff) = kernels_pair(&r.implicit, KernelMode::Implicit);
    assert_eq!(ioff.fused_ratio(), 0.0, "{label}: unfused program must report zero ratio");
    let run_oracle = |prog: &Arc<KernelProgram>| {
        let m = &r.implicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let xla = if w.uses_xla { fn_xla_for(m) } else { FnXla::default() };
        let mut o =
            bombyx::interp::oracle::Oracle::with_kernels(m, mem, xla, Arc::clone(prog));
        let v = o.run(w.entry, &w.args).expect("oracle");
        (
            v.as_i64(),
            memory_image(m, &o.memory),
            o.stats.calls,
            o.stats.spawns,
            o.stats.loads,
            o.stats.stores,
        )
    };
    assert_eq!(run_oracle(&ion), run_oracle(&ioff), "{label}: oracle fused-vs-unfused");

    let (eon, eoff) = kernels_pair(&r.explicit, KernelMode::Explicit);

    // Explicit machine.
    let run_explicit = |prog: &Arc<KernelProgram>| {
        let m = &r.explicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let xla = if w.uses_xla { fn_xla_for(m) } else { FnXla::default() };
        let mut ex = ExplicitExec::with_kernels(m, mem, xla, Arc::clone(prog));
        let v = ex.run(w.entry, &w.args).expect("explicit");
        assert_eq!(ex.live_closures(), 0);
        (
            v.as_i64(),
            memory_image(m, &ex.memory),
            ex.stats.tasks_run,
            ex.stats.closures_made,
            ex.stats.sends,
        )
    };
    assert_eq!(run_explicit(&eon), run_explicit(&eoff), "{label}: explicit fused-vs-unfused");

    // WS runtime, 4 workers.
    let run_ws = |prog: &Arc<KernelProgram>| {
        let m = &r.explicit;
        let mut seed = Memory::new(m);
        (w.init)(m, &mut seed);
        let mem = emu::shared_from(m, &seed);
        let cfg = WsConfig { workers: 4, steal_tries: 4 };
        let (v, mem, stats) = if w.uses_xla {
            let (w2, b2) = relax::weights(RELAX_SEED);
            let feat = m.global_by_name("feat");
            let sink = ScalarSink(move |_n: &str, args: &[Value], mem: &SharedMemory| {
                let n = args[0].as_i64() as usize;
                let feat = feat.expect("feat");
                relax_row(
                    n,
                    &mut |i| mem.load(feat, i),
                    &mut |i, v| mem.store(feat, i, v),
                    &w2,
                    &b2,
                )
            });
            ws::run_with_kernels(Arc::clone(prog), mem, w.entry, &w.args, &cfg, Box::new(sink))
                .expect("ws")
        } else {
            ws::run_with_kernels(
                Arc::clone(prog),
                mem,
                w.entry,
                &w.args,
                &cfg,
                Box::new(NoXlaSink),
            )
            .expect("ws")
        };
        (
            v.as_i64(),
            shared_memory_image(m, &mem),
            stats.tasks_run,
            stats.closures_made,
        )
    };
    assert_eq!(run_ws(&eon), run_ws(&eoff), "{label}: ws fused-vs-unfused");

    // Simulator: identical values, memory, cycle count and per-task
    // stats — the timed traces feed all of these.
    let run_sim = |prog: &Arc<KernelProgram>| {
        let m = &r.explicit;
        let mut mem = Memory::new(m);
        (w.init)(m, &mut mem);
        let cfg = SimConfig::default();
        let (v, mem, stats) = if w.uses_xla {
            let (w2, b2) = relax::weights(RELAX_SEED);
            let mut xla =
                SimScalarRelax { w: w2, b: b2, feat: m.global_by_name("feat").unwrap() };
            simulate_with_kernels(m, Arc::clone(prog), mem, w.entry, &w.args, &cfg, &mut xla)
                .expect("sim")
        } else {
            simulate_with_kernels(
                m,
                Arc::clone(prog),
                mem,
                w.entry,
                &w.args,
                &cfg,
                &mut NoSimXla,
            )
            .expect("sim")
        };
        (
            v.as_i64(),
            memory_image(m, &mem),
            stats.cycles,
            stats.tasks_run,
            stats.closures_made,
            format!("{:?}", stats.per_task),
        )
    };
    assert_eq!(run_sim(&eon), run_sim(&eoff), "{label}: sim fused-vs-unfused");

    // Byte-for-byte timed traces, dispatch by dispatch (xla tasks have
    // no kernel body to trace, so the relax workload is covered by the
    // engine-level cycle equality above instead).
    if !w.uses_xla {
        let t_on = collect_traces(&eon, &r.explicit, w, 5000);
        let t_off = collect_traces(&eoff, &r.explicit, w, 5000);
        assert_eq!(t_on.len(), t_off.len(), "{label}: dispatch counts differ");
        for (i, (a, b)) in t_on.iter().zip(&t_off).enumerate() {
            assert_eq!(a, b, "{label}: sim trace of dispatch #{i} not byte-identical");
        }
    }
}

#[test]
fn fusion_on_vs_off_differential_no_dae() {
    let opts = CompileOptions::no_dae();
    for w in corpus() {
        let r = compile(w.name, w.src, &opts).unwrap();
        check_fusion_differential(&w, &r, &format!("{} (dae=off)", w.name));
    }
}

#[test]
fn fusion_on_vs_off_differential_dae() {
    let opts = CompileOptions::standard();
    for w in corpus() {
        let r = compile(w.name, w.src, &opts).unwrap();
        check_fusion_differential(&w, &r, &format!("{} (dae=on)", w.name));
    }
}

#[test]
fn fused_programs_cut_dispatches_on_fib() {
    // Same task graph, fewer retired dispatches: the dynamic counterpart
    // of the static fused_ratio.
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let (on, off) = kernels_pair(&r.explicit, KernelMode::Explicit);
    assert!(on.fused_ratio() > 0.0, "fusion must fire on fib");
    let retired = |prog: &Arc<KernelProgram>| {
        let mut ex =
            ExplicitExec::with_kernels(&r.explicit, Memory::new(&r.explicit), NoXla, Arc::clone(prog));
        // `instrs` counts interpreter-retired dispatches; pin the
        // interpreter tier so a JIT-forcing environment (CI runs the
        // suite under BOMBYX_JIT_THRESHOLD=0) can't drain the counter.
        ex.set_jit(bombyx::exec::jit::JitConfig::disabled());
        ex.run("fib", &[Value::I64(12)]).unwrap();
        (ex.stats.tasks_run, ex.stats.instrs)
    };
    let (tasks_on, instrs_on) = retired(&on);
    let (tasks_off, instrs_off) = retired(&off);
    assert_eq!(tasks_on, tasks_off, "same task graph");
    assert!(
        instrs_on < instrs_off,
        "fused dispatch count must shrink: {instrs_on} vs {instrs_off}"
    );
}

#[test]
fn kernels_timed_appends_pass_timing_once() {
    use bombyx::lower::CompileSession;
    let mut session =
        CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let before = session.timings().len();
    session.kernels_timed().unwrap();
    let after_first = session.timings().len();
    assert_eq!(after_first, before + 1, "kernel_compile timing appended");
    assert!(session.timings().iter().any(|t| t.pass == "kernel_compile" && t.ran));
    session.kernels_timed().unwrap();
    assert_eq!(session.timings().len(), after_first, "second request is cached");
}
