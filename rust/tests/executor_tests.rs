//! Integration tests for the resident multi-job executor: interleaved
//! mixed-corpus determinism, cooperative cancellation, fair admission,
//! stats parity with the one-shot wrapper, and idle buffer reclamation.

use std::time::Duration;

use bombyx::coordinator::WsServeExperiment;
use bombyx::ir::Value;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::workloads::{bfs, fib, graphgen};
use bombyx::ws::{self, Executor, ExecutorConfig, WsConfig};

fn fib_session() -> CompileSession {
    CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap()
}

#[test]
fn flood_32_jobs_matches_one_shot_across_worker_counts() {
    let exp = WsServeExperiment::new().unwrap();
    const JOBS: usize = 32;
    // Reference images from sequential one-shot single-worker runs.
    let mut reference = Vec::with_capacity(JOBS);
    for i in 0..JOBS {
        let (value, mem, _) = exp.one_shot(i, 1).unwrap();
        reference.push((value, exp.memory_image(i, &mem)));
    }
    for workers in [1usize, 4] {
        let config = ExecutorConfig {
            ws: WsConfig { workers, steal_tries: 4 },
            ..ExecutorConfig::default()
        };
        let executor = Executor::new(config).unwrap();
        let handles: Vec<_> =
            (0..JOBS).map(|i| executor.submit(exp.job(i).unwrap()).unwrap()).collect();
        for (i, handle) in handles.into_iter().enumerate() {
            let (value, mem, _) = handle.join().unwrap();
            assert_eq!(value, reference[i].0, "job {i} root result, workers={workers}");
            assert_eq!(
                exp.memory_image(i, &mem),
                reference[i].1,
                "job {i} final memory, workers={workers}"
            );
        }
        assert_eq!(executor.stats().jobs_completed, JOBS as u64);
        assert_eq!(executor.stats().jobs_failed, 0);
    }
}

#[test]
fn cancel_sweeps_live_closures_to_zero() {
    let session = fib_session();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 2, steal_tries: 4 },
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let handle = executor.submit(session.ws_job("fib", &[Value::I64(30)]).unwrap()).unwrap();
    // Let the job build up a live working set before cancelling.
    while handle.stats().tasks_run < 1_000 && !handle.is_finished() {
        std::thread::sleep(Duration::from_millis(1));
    }
    handle.cancel();
    handle.wait();
    assert_eq!(handle.live_closures(), 0, "cancellation must sweep the job's closure arena");
    let err = handle.join().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert_eq!(executor.stats().jobs_cancelled, 1);
}

#[test]
fn small_jobs_progress_alongside_a_flooding_job() {
    // Fairness smoke: joins of the small jobs must terminate while a
    // much larger resident job keeps the pool saturated (round-robin
    // injector lanes + the periodic injector poll prevent starvation —
    // without them this test hangs until the big job drains).
    let session = fib_session();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 2, steal_tries: 4 },
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let big = executor.submit(session.ws_job("fib", &[Value::I64(30)]).unwrap()).unwrap();
    let smalls: Vec<_> = (0..8)
        .map(|_| executor.submit(session.ws_job("fib", &[Value::I64(10)]).unwrap()).unwrap())
        .collect();
    for handle in smalls {
        let (v, _, _) = handle.join().unwrap();
        assert_eq!(v.as_i64(), fib::fib_ref(10) as i64);
    }
    // Don't pay for the rest of fib(30).
    big.cancel();
    big.wait();
    assert_eq!(big.live_closures(), 0);
}

#[test]
fn executor_stats_match_one_shot_run_at_one_worker() {
    // At one worker execution order is deterministic, so every per-job
    // stat of a submitted job must equal the one-shot wrapper's.
    let session = fib_session();
    let cfg = WsConfig { workers: 1, steal_tries: 4 };
    let (v_ref, _, s_ref) = ws::run_with_kernels(
        session.explicit_kernels().unwrap(),
        session.shared_memory(),
        "fib",
        &[Value::I64(18)],
        &cfg,
        Box::new(ws::NoXlaSink),
    )
    .unwrap();
    let executor =
        Executor::new(ExecutorConfig { ws: cfg, ..ExecutorConfig::default() }).unwrap();
    let handle = executor.submit(session.ws_job("fib", &[Value::I64(18)]).unwrap()).unwrap();
    let (v, _, s) = handle.join().unwrap();
    assert_eq!(v.as_i64(), v_ref.as_i64());
    assert_eq!(s.tasks_run, s_ref.tasks_run);
    assert_eq!((s.steals, s_ref.steals), (0, 0));
    assert_eq!(s.closures_made, s_ref.closures_made);
    assert_eq!(s.max_live_closures, s_ref.max_live_closures);
    assert_eq!(s.instrs, s_ref.instrs);
    assert_eq!(s.xla_batches, s_ref.xla_batches);
    assert_eq!(s.xla_tasks, s_ref.xla_tasks);
}

#[test]
fn retired_deque_buffers_are_freed_once_idle() {
    // A 200-wide root fan-out pushes 200 tasks into the 64-slot initial
    // deque buffer before the single worker pops any of them, forcing
    // growth (and buffer retirement); once the job joins and the
    // executor is quiescent, the retired buffers must be freed rather
    // than accrue until drop.
    let session = CompileSession::new("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    let m = session.explicit();
    let graph = graphgen::tree(200, 2);
    let mut job = session.ws_job("visit", &[Value::I64(0)]).unwrap();
    job.memory.fill_i64(m.global_by_name("adj_off").unwrap(), &graph.adj_off);
    job.memory.fill_i64(m.global_by_name("adj_edges").unwrap(), &graph.adj_edges);
    job.memory.resize(m.global_by_name("visited").unwrap(), graph.nodes());
    let executor = Executor::new(ExecutorConfig {
        ws: WsConfig { workers: 1, steal_tries: 4 },
        ..ExecutorConfig::default()
    })
    .unwrap();
    let handle = executor.submit(job).unwrap();
    let (_, mem, stats) = handle.join().unwrap();
    assert_eq!(mem.dump_i64(m.global_by_name("visited").unwrap()), vec![1; graph.nodes()]);
    assert!(stats.tasks_run as usize >= graph.nodes());
    assert_eq!(executor.retired_buffers(), 0, "idle reclamation must free outgrown buffers");
}

#[test]
fn admission_limits_active_jobs_and_drains_the_queue() {
    let exp = WsServeExperiment::new().unwrap();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 2, steal_tries: 4 },
        max_active_jobs: 1,
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let n = 2 * exp.corpus_len();
    let handles: Vec<_> = (0..n).map(|i| executor.submit(exp.job(i).unwrap()).unwrap()).collect();
    for (i, handle) in handles.into_iter().enumerate() {
        let (value, mem, _) = handle.join().unwrap();
        exp.verify(i, &value, &mem).unwrap();
    }
    let stats = executor.stats();
    assert_eq!(stats.jobs_submitted, n as u64);
    assert_eq!(stats.jobs_completed, n as u64);
    assert_eq!(stats.jobs_failed, 0);
}

#[test]
fn cancel_while_queued_completes_without_running() {
    let session = fib_session();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 1, steal_tries: 4 },
        max_active_jobs: 1,
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let big = executor.submit(session.ws_job("fib", &[Value::I64(28)]).unwrap()).unwrap();
    let queued = executor.submit(session.ws_job("fib", &[Value::I64(20)]).unwrap()).unwrap();
    queued.cancel();
    queued.wait();
    assert_eq!(queued.live_closures(), 0);
    assert_eq!(queued.stats().tasks_run, 0, "a job cancelled in the admission queue never runs");
    let err = queued.join().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    big.cancel();
    big.wait();
    assert!(executor.stats().jobs_cancelled >= 1);
}
