//! Fault-containment integration tests for the resident executor:
//! panic isolation, supervisor respawn, per-job deadlines, retry with
//! deterministic backoff, bounded-admission shedding, and same-seed
//! chaos determinism.
//!
//! Every test pins an explicit `FaultPlan` (often `disabled()` plus
//! forced faults), so the suite is deterministic even under the CI
//! chaos-smoke environment — except `env_chaos_smoke_converges`, which
//! exists precisely to exercise the `BOMBYX_CHAOS` env fallback and
//! no-ops when the variable is unset.

use std::time::Duration;

use bombyx::coordinator::WsServeExperiment;
use bombyx::ir::Value;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::workloads::fib;
use bombyx::ws::{
    self, Executor, ExecutorConfig, FaultPlan, ForcedFault, InjectedFault, JobErrorKind, JobSpec,
    RetryPolicy, Trap, WsConfig,
};

fn fib_session() -> CompileSession {
    CompileSession::new("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap()
}

/// A forced panic at the first dispatch of one job out of 32 must fail
/// exactly that job (`Panicked`, caught — no worker dies) and leave the
/// other 31 byte-identical to their clean one-shot references.
#[test]
fn forced_panic_is_contained_to_its_job() {
    let exp = WsServeExperiment::new().unwrap();
    const JOBS: usize = 32;
    const POISONED: usize = 7;
    let mut reference = Vec::with_capacity(JOBS);
    for i in 0..JOBS {
        let (value, mem, _) = exp.one_shot(i, 1).unwrap();
        reference.push((value, exp.memory_image(i, &mem)));
    }
    let config = ExecutorConfig {
        ws: WsConfig { workers: 4, steal_tries: 4 },
        fault: Some(FaultPlan {
            force: vec![ForcedFault {
                job: POISONED as u64,
                attempt: 1,
                kind: InjectedFault::Panic,
                at: 1,
            }],
            ..FaultPlan::disabled()
        }),
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let handles: Vec<_> =
        (0..JOBS).map(|i| executor.submit(exp.job(i).unwrap()).unwrap()).collect();
    for (i, handle) in handles.into_iter().enumerate() {
        if i == POISONED {
            let err = handle.join().unwrap_err();
            assert_eq!(err.kind(), JobErrorKind::Panicked, "{err}");
            assert!(err.to_string().contains("injected panic"), "{err}");
        } else {
            let (value, mem, _) = handle.join().unwrap();
            assert_eq!(value, reference[i].0, "job {i} root result next to a panicked job");
            assert_eq!(
                exp.memory_image(i, &mem),
                reference[i].1,
                "job {i} final memory next to a panicked job"
            );
        }
    }
    let stats = executor.stats();
    assert_eq!(stats.jobs_completed, (JOBS - 1) as u64);
    assert_eq!(stats.jobs_failed, 1, "the panic must be charged exactly once");
    assert_eq!(stats.jobs_retried, 0, "panics are not retryable by default");
    assert_eq!(stats.workers_respawned, 0, "a caught panic must not kill the worker");
}

/// A one-shot worker death outside the task catch must be repaired by
/// the supervisor — the flood still verifies end to end and the respawn
/// is counted exactly once.
#[test]
fn supervisor_respawns_a_killed_worker() {
    let exp = WsServeExperiment::new().unwrap();
    const JOBS: usize = 32;
    let config = ExecutorConfig {
        ws: WsConfig { workers: 4, steal_tries: 4 },
        fault: Some(FaultPlan { kill_worker: Some((2, 1)), ..FaultPlan::disabled() }),
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let handles: Vec<_> =
        (0..JOBS).map(|i| executor.submit(exp.job(i).unwrap()).unwrap()).collect();
    for (i, handle) in handles.into_iter().enumerate() {
        let (value, mem, _) = handle.join().unwrap();
        exp.verify(i, &value, &mem).unwrap();
    }
    let stats = executor.stats();
    assert_eq!(stats.jobs_completed, JOBS as u64);
    assert_eq!(stats.jobs_failed, 0, "a worker death must not fail any job");
    assert_eq!(stats.workers_respawned, 1, "exactly one respawn for the one-shot kill");
}

/// A cooperative deadline fires at a dispatch boundary of a resident
/// fib(30) long before the job could finish; the join returns a
/// structured `DeadlineExceeded` instead of hanging.
#[test]
fn deadline_fails_a_long_job_at_a_dispatch_boundary() {
    let session = fib_session();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 2, steal_tries: 4 },
        fault: Some(FaultPlan::disabled()),
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let spec = JobSpec { deadline: Some(Duration::from_millis(30)), ..JobSpec::default() };
    let job = session.ws_job("fib", &[Value::I64(30)]).unwrap().with_spec(spec);
    let handle = executor.submit(job).unwrap();
    handle.wait();
    assert_eq!(handle.live_closures(), 0, "a deadlined job must sweep its closure arena");
    let err = handle.join().unwrap_err();
    assert_eq!(err.kind(), JobErrorKind::DeadlineExceeded, "{err}");
    assert!(err.to_string().contains("deadline"), "{err}");
    assert_eq!(executor.stats().jobs_failed, 1);
    assert_eq!(executor.stats().jobs_retried, 0, "deadlines are not retryable");
}

/// A fuel budget far below fib(20)'s dispatch count trips the
/// deterministic `Trap::Fuel` path.
#[test]
fn fuel_budget_traps_deterministically() {
    let session = fib_session();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 1, steal_tries: 4 },
        fault: Some(FaultPlan::disabled()),
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let spec = JobSpec { fuel_budget: Some(50), ..JobSpec::default() };
    let job = session.ws_job("fib", &[Value::I64(20)]).unwrap().with_spec(spec);
    let err = executor.submit(job).unwrap().join().unwrap_err();
    assert_eq!(err.kind(), JobErrorKind::Trap(Trap::Fuel), "{err}");
    assert!(err.to_string().contains("fuel budget"), "{err}");
}

/// Two forced transients (attempts 1 and 2) with a 4-attempt retry
/// policy: the job converges on attempt 3, retries are counted, and the
/// job's latency covers the exact deterministic backoff schedule.
#[test]
fn transient_faults_retry_with_deterministic_backoff() {
    let session = fib_session();
    let policy =
        RetryPolicy { max_attempts: 4, backoff: Duration::from_millis(5), retry_on_panic: false };
    let force = [1u32, 2]
        .iter()
        .map(|&attempt| ForcedFault { job: 0, attempt, kind: InjectedFault::Transient, at: 3 })
        .collect();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 2, steal_tries: 4 },
        fault: Some(FaultPlan { force, ..FaultPlan::disabled() }),
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let spec = JobSpec { retry: policy.clone(), ..JobSpec::default() };
    let job = session.ws_job("fib", &[Value::I64(18)]).unwrap().with_spec(spec);
    let handle = executor.submit(job).unwrap();
    handle.wait();
    let attempts = handle.attempts();
    let latency = handle.latency().expect("job finished");
    let (value, _, _) = handle.join().unwrap();
    assert_eq!(value.as_i64(), fib::fib_ref(18) as i64, "the surviving attempt must verify");
    assert_eq!(attempts, 3, "two transients then success");
    let stats = executor.stats();
    assert_eq!(stats.jobs_retried, 2);
    assert_eq!(stats.jobs_completed, 1);
    assert_eq!(stats.jobs_failed, 0, "a retried-then-converged job is not a failure");
    // The backoff schedule is a pure function of (job, attempt); the
    // job's end-to-end latency must cover both waits.
    let scheduled = policy.delay_for(0, 2) + policy.delay_for(0, 3);
    assert!(
        latency >= scheduled,
        "latency {latency:?} must cover the deterministic backoff {scheduled:?}"
    );
}

/// With one active slot and one queue slot, a third concurrent
/// submission is shed with a structured error instead of queueing
/// unboundedly.
#[test]
fn full_admission_queue_sheds_submissions() {
    let session = fib_session();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 1, steal_tries: 4 },
        max_active_jobs: 1,
        max_queued_jobs: 1,
        fault: Some(FaultPlan::disabled()),
        ..ExecutorConfig::default()
    };
    let executor = Executor::new(config).unwrap();
    let big = executor.submit(session.ws_job("fib", &[Value::I64(28)]).unwrap()).unwrap();
    let queued = executor.submit(session.ws_job("fib", &[Value::I64(20)]).unwrap()).unwrap();
    let err = executor.submit(session.ws_job("fib", &[Value::I64(10)]).unwrap()).unwrap_err();
    assert_eq!(err.kind(), JobErrorKind::Shed, "{err}");
    assert!(err.to_string().contains("shed"), "{err}");
    assert_eq!(executor.stats().jobs_shed, 1);
    queued.cancel();
    queued.wait();
    big.cancel();
    big.wait();
    assert_eq!(executor.stats().jobs_shed, 1, "cancellations must not recount sheds");
}

/// Two chaos floods under the same seed produce identical per-job
/// outcome sequences, and every non-shed job converges (the retry
/// horizon outlasts the fault-free cutoff).
#[test]
fn same_seed_chaos_floods_have_identical_outcomes() {
    let exp = WsServeExperiment::new().unwrap();
    let jobs = 2 * exp.corpus_len();
    let a = exp.flood_chaos(2, jobs, 1, 7).unwrap();
    let b = exp.flood_chaos(2, jobs, 1, 7).unwrap();
    assert_eq!(a.outcomes, b.outcomes, "same seed, same per-job outcomes");
    assert_eq!(a.verified + a.failed, jobs);
    for (i, outcome) in a.outcomes.iter().enumerate() {
        assert!(
            outcome.is_none() || outcome.as_deref() == Some("shed"),
            "job {i}: non-shed chaos job must converge, got {outcome:?}"
        );
    }
}

/// The `BOMBYX_CHAOS` env fallback, exercised by the CI chaos-smoke job
/// (two fixed seeds). No-op when the variable is unset — every other
/// test in this suite pins an explicit plan instead.
#[test]
fn env_chaos_smoke_converges() {
    let armed = std::env::var(ws::fault::ENV_CHAOS).map(|v| !v.trim().is_empty()).unwrap_or(false);
    if !armed {
        return;
    }
    let exp = WsServeExperiment::new().unwrap();
    let config = ExecutorConfig {
        ws: WsConfig { workers: 2, steal_tries: 4 },
        default_spec: JobSpec {
            retry: RetryPolicy {
                max_attempts: 6,
                backoff: Duration::from_millis(2),
                retry_on_panic: true,
            },
            ..JobSpec::default()
        },
        // `fault: None` is the point: Executor::new must pick the plan
        // up from the environment.
        fault: None,
        ..ExecutorConfig::default()
    };
    let report = exp.flood_with_config(config, 2 * exp.corpus_len(), 1).unwrap();
    for (i, outcome) in report.outcomes.iter().enumerate() {
        assert!(
            outcome.is_none() || outcome.as_deref() == Some("shed"),
            "job {i}: non-shed job must converge under env chaos, got {outcome:?}"
        );
    }
}
