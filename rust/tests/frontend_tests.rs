//! Integration tests: frontend behaviour over whole example files.

use bombyx::frontend::parse_and_check;
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

#[test]
fn all_bundled_workloads_parse_and_check() {
    for (name, src) in [
        ("fib", fib::FIB_SRC),
        ("bfs", bfs::BFS_SRC),
        ("bfs_dae", bfs::BFS_DAE_SRC),
        ("nqueens", nqueens::NQUEENS_SRC),
        ("qsort", qsort::QSORT_SRC),
        ("relax", relax::RELAX_SRC),
    ] {
        parse_and_check(name, src).unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
}

#[test]
fn example_cilk_files_parse() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/cilk");
    let mut count = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("cilk") {
            let src = std::fs::read_to_string(&path).unwrap();
            parse_and_check(path.to_str().unwrap(), &src)
                .unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
            count += 1;
        }
    }
    assert!(count >= 5, "expected at least 5 example programs, found {count}");
}

#[test]
fn diagnostics_carry_location() {
    let err = parse_and_check("t.cilk", "int f(int n) {\n  return m;\n}").unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("t.cilk:2"), "{text}");
    assert!(text.contains("unknown variable"), "{text}");
}

#[test]
fn error_recovery_is_not_required_first_error_reported() {
    let err = parse_and_check("t", "int f(int n) { return n + ; }").unwrap_err();
    assert!(format!("{err:#}").contains("expected an expression"));
}

#[test]
fn deeply_nested_expressions_do_not_overflow() {
    let mut expr = String::from("n");
    for _ in 0..200 {
        expr = format!("({expr} + 1)");
    }
    let src = format!("int f(int n) {{ return {expr}; }}");
    parse_and_check("deep", &src).unwrap();
}
