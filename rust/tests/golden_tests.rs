//! Golden tests: the paper's figures as stable text artifacts, plus
//! cross-language parity pins and the per-pass IR snapshot harness built
//! on the `PassManager` snapshot hook.

use bombyx::ir::print::{print_cilk1, print_func, print_module};
use bombyx::lower::{compile, Artifact, CompileOptions, PassManager};
use bombyx::util::golden::check_golden;
use bombyx::workloads::fib;

#[test]
fn fig2_cilk1_fib_golden() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.explicit;
    let entry = &m.funcs[m.func_by_name("fib").unwrap()];
    let cont = &m.funcs[m.func_by_name("fib__k1").unwrap()];
    let entry_text = print_cilk1(m, entry);
    let cont_text = print_cilk1(m, cont);

    // Paper Fig. 2 shape (modulo task naming):
    //   task fib (cont int k, int n) {
    //     if (n < 2) send_argument(k, n);
    //     else { spawn_next sum(k, ?x, ?y); spawn fib(x, n-1); ... }
    //   }
    //   task sum (cont int k, int x, int y) { send_argument(k, x + y); }
    assert!(entry_text.contains("task fib (cont int k, int n)"), "{entry_text}");
    assert!(entry_text.contains("send_argument(k, n)"), "{entry_text}");
    assert!(entry_text.contains("spawn_next fib__k1(k, ?x, ?y)"), "{entry_text}");
    assert!(entry_text.contains("spawn fib(c"), "{entry_text}");
    assert!(entry_text.contains("n - 1"), "{entry_text}");
    assert!(entry_text.contains("n - 2"), "{entry_text}");
    assert!(cont_text.contains("task fib__k1 (cont int k, int x, int y)"), "{cont_text}");
    assert!(cont_text.contains("send_argument(k, x + y)"), "{cont_text}");
}

#[test]
fn fig4b_implicit_ir_golden() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.implicit;
    let f = &m.funcs[m.func_by_name("fib").unwrap()];
    let text = print_func(m, f);
    // Single entry; `sync` as a terminator; spawns in the body (Fig. 4(b)).
    assert!(text.contains("(entry)"), "{text}");
    assert!(text.contains("T: sync -> "), "{text}");
    assert!(text.contains("x = spawn fib(n - 1)"), "{text}");
    assert!(text.contains("y = spawn fib(n - 2)"), "{text}");
    assert!(text.contains("T: return x + y"), "{text}");
}

#[test]
fn fig4c_explicit_ir_golden() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.explicit;
    let f = &m.funcs[m.func_by_name("fib").unwrap()];
    let text = print_func(m, f);
    assert!(text.contains("spawn_next fib__k1"), "{text}");
    assert!(text.contains("close c"), "{text}");
    assert!(text.contains("send_argument(k, n)"), "{text}");
    assert!(!text.contains("T: sync"), "no sync survives:\n{text}");
}

#[test]
fn weight_parity_with_python_golden() {
    // Mirrors python/tests/test_kernel.py::test_rng_matches_rust_golden —
    // the same four values, same seed. If either side's PRNG drifts, both
    // suites fail on the same constant.
    let (w, _) = bombyx::workloads::relax::weights(1);
    let golden: [f32; 4] = [-0.051488318, 0.085822836, -0.032146744, -0.06721322];
    assert_eq!(&w[..4], &golden);
}

/// Satellite of the RTL PR: the `PassManager` snapshot hook wired into a
/// golden harness. The IR after **every** executed pass of the standard
/// pipeline on `examples/cilk/fib.cilk` is diffed against a checked-in
/// golden, so any pass-ordering or lowering drift shows up as a per-pass
/// diff rather than only at the final explicit dump. Goldens self-bless
/// when missing; `BOMBYX_STRICT_GOLDENS=1` (set in CI) turns a mismatch
/// into a failure, and `BOMBYX_UPDATE_GOLDENS=1` re-blesses.
#[test]
fn per_pass_ir_snapshots_match_goldens() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/cilk/fib.cilk");
    let src = std::fs::read_to_string(path).unwrap();
    let (program, _) = bombyx::frontend::parse_and_check("fib", &src).unwrap();
    let manager = PassManager::standard();
    let opts = CompileOptions::standard();
    let mut snaps: Vec<(&'static str, String)> = Vec::new();
    manager
        .run(Artifact::Ast(program), &opts, |pass, artifact| {
            if let Some(m) = artifact.as_module() {
                snaps.push((pass, print_module(m)));
            }
        })
        .unwrap();
    assert_eq!(snaps.len(), 5, "standard pipeline runs five passes on fib");
    for (i, (pass, text)) in snaps.iter().enumerate() {
        let rel = format!("rust/tests/goldens/passes/fib/{i:02}_{pass}.golden");
        check_golden(&rel, text);
    }
}

#[test]
fn per_pass_snapshots_are_deterministic() {
    let run_once = || {
        let r = compile("fib", fib::FIB_SRC, &CompileOptions::standard()).unwrap();
        print_module(&r.explicit)
    };
    assert_eq!(run_once(), run_once());
}

#[test]
fn stage_trace_is_stable_across_recompiles() {
    let a = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let b = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    assert_eq!(
        bombyx::ir::print::print_module(&a.explicit),
        bombyx::ir::print::print_module(&b.explicit)
    );
}
