//! Golden tests: the paper's figures as stable text artifacts, plus
//! cross-language parity pins.

use bombyx::ir::print::{print_cilk1, print_func};
use bombyx::lower::{compile, CompileOptions};
use bombyx::workloads::fib;

#[test]
fn fig2_cilk1_fib_golden() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.explicit;
    let entry = &m.funcs[m.func_by_name("fib").unwrap()];
    let cont = &m.funcs[m.func_by_name("fib__k1").unwrap()];
    let entry_text = print_cilk1(m, entry);
    let cont_text = print_cilk1(m, cont);

    // Paper Fig. 2 shape (modulo task naming):
    //   task fib (cont int k, int n) {
    //     if (n < 2) send_argument(k, n);
    //     else { spawn_next sum(k, ?x, ?y); spawn fib(x, n-1); ... }
    //   }
    //   task sum (cont int k, int x, int y) { send_argument(k, x + y); }
    assert!(entry_text.contains("task fib (cont int k, int n)"), "{entry_text}");
    assert!(entry_text.contains("send_argument(k, n)"), "{entry_text}");
    assert!(entry_text.contains("spawn_next fib__k1(k, ?x, ?y)"), "{entry_text}");
    assert!(entry_text.contains("spawn fib(c"), "{entry_text}");
    assert!(entry_text.contains("n - 1"), "{entry_text}");
    assert!(entry_text.contains("n - 2"), "{entry_text}");
    assert!(cont_text.contains("task fib__k1 (cont int k, int x, int y)"), "{cont_text}");
    assert!(cont_text.contains("send_argument(k, x + y)"), "{cont_text}");
}

#[test]
fn fig4b_implicit_ir_golden() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.implicit;
    let f = &m.funcs[m.func_by_name("fib").unwrap()];
    let text = print_func(m, f);
    // Single entry; `sync` as a terminator; spawns in the body (Fig. 4(b)).
    assert!(text.contains("(entry)"), "{text}");
    assert!(text.contains("T: sync -> "), "{text}");
    assert!(text.contains("x = spawn fib(n - 1)"), "{text}");
    assert!(text.contains("y = spawn fib(n - 2)"), "{text}");
    assert!(text.contains("T: return x + y"), "{text}");
}

#[test]
fn fig4c_explicit_ir_golden() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let m = &r.explicit;
    let f = &m.funcs[m.func_by_name("fib").unwrap()];
    let text = print_func(m, f);
    assert!(text.contains("spawn_next fib__k1"), "{text}");
    assert!(text.contains("close c"), "{text}");
    assert!(text.contains("send_argument(k, n)"), "{text}");
    assert!(!text.contains("T: sync"), "no sync survives:\n{text}");
}

#[test]
fn weight_parity_with_python_golden() {
    // Mirrors python/tests/test_kernel.py::test_rng_matches_rust_golden —
    // the same four values, same seed. If either side's PRNG drifts, both
    // suites fail on the same constant.
    let (w, _) = bombyx::workloads::relax::weights(1);
    let golden: [f32; 4] = [-0.051488318, 0.085822836, -0.032146744, -0.06721322];
    assert_eq!(&w[..4], &golden);
}

#[test]
fn stage_trace_is_stable_across_recompiles() {
    let a = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let b = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    assert_eq!(
        bombyx::ir::print::print_module(&a.explicit),
        bombyx::ir::print::print_module(&b.explicit)
    );
}
