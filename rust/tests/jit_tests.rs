//! Differential tests for the native JIT tier.
//!
//! The interpreter is the oracle: for every corpus workload, under both
//! DAE variants, a forced-JIT run (threshold 0 — native from the first
//! dispatch) must produce the same value, the same memory image and the
//! same deterministic task/closure counters as a JIT-disabled run of the
//! same engine — on the kernel oracle, the explicit machine and the WS
//! runtime at 1 and 4 workers. On targets where native codegen is
//! unavailable the forced tier silently stays interpreted and the
//! differential is vacuous (still green); the tests that assert native
//! entries happened guard on [`jit::available`].

use std::sync::Arc;

use bombyx::backend::emu;
use bombyx::exec::jit::{self, JitConfig};
use bombyx::exec::{compile_module, KernelMode, KernelProgram};
use bombyx::interp::explicit_exec::ExplicitExec;
use bombyx::interp::{FnXla, Memory, NoXla};
use bombyx::ir::cfg::Module;
use bombyx::ir::expr::Value;
use bombyx::lower::{compile, CompileOptions, CompileResult};
use bombyx::workloads::{bfs, fib, graphgen, nqueens, qsort, relax, rmw};
use bombyx::ws::{Executor, ExecutorConfig, Job, ScalarSink, SharedMemory, WsConfig};

const RELAX_SEED: u64 = 5;

struct Workload {
    name: &'static str,
    src: &'static str,
    entry: &'static str,
    args: Vec<Value>,
    init: Box<dyn Fn(&Module, &mut Memory)>,
    uses_xla: bool,
}

fn corpus() -> Vec<Workload> {
    let bfs_graph = graphgen::tree(3, 4); // 121 nodes
    let bfs_graph2 = graphgen::tree(3, 4);
    let relax_graph = graphgen::tree(3, 3); // 40 nodes
    let qsort_input: Vec<i64> = (0..48).map(|i| ((i * 37 + 11) % 100) - 50).collect();
    vec![
        Workload {
            name: "fib",
            src: fib::FIB_SRC,
            entry: "fib",
            args: vec![Value::I64(12)],
            init: Box::new(|_, _| {}),
            uses_xla: false,
        },
        Workload {
            name: "bfs",
            src: bfs::BFS_SRC,
            entry: "visit",
            args: vec![Value::I64(0)],
            init: Box::new(move |m, mem| bfs::init_memory(m, mem, &bfs_graph).unwrap()),
            uses_xla: false,
        },
        Workload {
            name: "bfs_dae",
            src: bfs::BFS_DAE_SRC,
            entry: "visit",
            args: vec![Value::I64(0)],
            init: Box::new(move |m, mem| bfs::init_memory(m, mem, &bfs_graph2).unwrap()),
            uses_xla: false,
        },
        Workload {
            name: "nqueens",
            src: nqueens::NQUEENS_SRC,
            entry: "place",
            args: [6i64, 0, 0, 0, 0].iter().map(|&v| Value::I64(v)).collect(),
            init: Box::new(|_, _| {}),
            uses_xla: false,
        },
        Workload {
            name: "qsort",
            src: qsort::QSORT_SRC,
            entry: "qsort_",
            args: vec![Value::I64(0), Value::I64(47)],
            init: Box::new(move |m, mem| {
                mem.fill_i64(m.global_by_name("data").unwrap(), &qsort_input);
            }),
            uses_xla: false,
        },
        Workload {
            name: "relax",
            src: relax::RELAX_SRC,
            entry: "expand",
            args: vec![Value::I64(0)],
            init: Box::new(move |m, mem| {
                relax::init_memory(m, mem, &relax_graph, RELAX_SEED).unwrap()
            }),
            uses_xla: true,
        },
        // Fused-superinstruction shapes (load→bin→store triples,
        // bin→atomic_add, bin→send_argument) under the helper replay.
        Workload {
            name: "rmw",
            src: rmw::RMW_SRC,
            entry: "bump",
            args: vec![Value::I64(0), Value::I64(rmw::N as i64)],
            init: Box::new(|m, mem| rmw::init_memory(m, mem).unwrap()),
            uses_xla: false,
        },
    ]
}

type Image = Vec<(String, Vec<i64>, Vec<u32>)>;

fn memory_image(module: &Module, mem: &Memory) -> Image {
    module
        .globals
        .iter()
        .map(|(gid, g)| {
            let ints = mem.dump_i64(gid);
            let floats = mem.dump_f32(gid).iter().map(|f| f.to_bits()).collect();
            (g.name.clone(), ints, floats)
        })
        .collect()
}

fn shared_memory_image(module: &Module, mem: &SharedMemory) -> Image {
    module
        .globals
        .iter()
        .map(|(gid, g)| {
            let ints = mem.dump_i64(gid);
            let floats = mem.dump_f32(gid).iter().map(|f| f.to_bits()).collect();
            (g.name.clone(), ints, floats)
        })
        .collect()
}

fn relax_row(
    n: usize,
    read: &mut dyn FnMut(i64) -> anyhow::Result<Value>,
    write: &mut dyn FnMut(i64, Value) -> anyhow::Result<()>,
    w: &[f32],
    b: &[f32],
) -> anyhow::Result<Value> {
    let f = relax::F;
    let x: Vec<f32> = (0..f)
        .map(|j| read((n * f + j) as i64).map(|v| v.as_f32()))
        .collect::<anyhow::Result<_>>()?;
    let (y, score) = relax::relax_ref(&x, w, b);
    for (j, &v) in y.iter().enumerate() {
        write((n * f + j) as i64, Value::F32(v))?;
    }
    Ok(Value::I64((score * 1000.0) as i64))
}

fn fn_xla_for(module: &Module) -> FnXla {
    let mut handler = FnXla::default();
    let feat = module.global_by_name("feat").expect("relax module has feat");
    let (w, b) = relax::weights(RELAX_SEED);
    handler.register("relax", move |args: &[Value], mem: &mut Memory| {
        let n = args[0].as_i64() as usize;
        relax_row(n, &mut |i| mem.load(feat, i), &mut |i, v| mem.store(feat, i, v), &w, &b)
    });
    handler
}

// ---------------------------------------------------------------------------
// Per-engine runners, parameterized over the tier config

fn run_oracle(w: &Workload, r: &CompileResult, cfg: JitConfig) -> (i64, Image, u64, u64, u64, u64) {
    let m = &r.implicit;
    let mut mem = Memory::new(m);
    (w.init)(m, &mut mem);
    let xla = if w.uses_xla { fn_xla_for(m) } else { FnXla::default() };
    let mut o = bombyx::interp::oracle::Oracle::new(m, mem, xla);
    o.set_jit(cfg);
    let v = o.run(w.entry, &w.args).expect("oracle");
    (
        v.as_i64(),
        memory_image(m, &o.memory),
        o.stats.calls,
        o.stats.spawns,
        o.stats.loads,
        o.stats.stores,
    )
}

fn run_explicit(w: &Workload, r: &CompileResult, cfg: JitConfig) -> (i64, Image, u64, u64, u64) {
    let m = &r.explicit;
    let mut mem = Memory::new(m);
    (w.init)(m, &mut mem);
    let xla = if w.uses_xla { fn_xla_for(m) } else { FnXla::default() };
    let mut ex = ExplicitExec::new(m, mem, xla);
    ex.set_jit(cfg);
    let v = ex.run(w.entry, &w.args).expect("explicit");
    assert_eq!(ex.live_closures(), 0, "{}: explicit closure leak", w.name);
    (
        v.as_i64(),
        memory_image(m, &ex.memory),
        ex.stats.tasks_run,
        ex.stats.closures_made,
        ex.stats.sends,
    )
}

/// One job through the resident executor, with the tier pinned per-job
/// via `ExecutorConfig::jit` (the seam the WS runtime resolves tiers
/// through at submission).
fn run_ws(
    w: &Workload,
    r: &CompileResult,
    kernels: &Arc<KernelProgram>,
    cfg: JitConfig,
    workers: usize,
) -> (i64, Image, u64, u64) {
    let m = &r.explicit;
    let mut seed = Memory::new(m);
    (w.init)(m, &mut seed);
    let mem = emu::shared_from(m, &seed);
    let mut job = Job::new(Arc::clone(kernels), mem, w.entry, &w.args);
    if w.uses_xla {
        let (w2, b2) = relax::weights(RELAX_SEED);
        let feat = m.global_by_name("feat");
        job.xla_sink = Box::new(ScalarSink(move |_n: &str, args: &[Value], mem: &SharedMemory| {
            let n = args[0].as_i64() as usize;
            let feat = feat.expect("feat");
            relax_row(n, &mut |i| mem.load(feat, i), &mut |i, v| mem.store(feat, i, v), &w2, &b2)
        }));
    }
    let executor = Executor::new(ExecutorConfig {
        ws: WsConfig { workers, steal_tries: 4 },
        jit: Some(cfg),
        ..ExecutorConfig::default()
    })
    .unwrap();
    let handle = executor.submit(job).unwrap();
    let (v, mem, stats) = handle.join().expect("ws job");
    (v.as_i64(), shared_memory_image(m, &mem), stats.tasks_run, stats.closures_made)
}

fn check_jit_differential(w: &Workload, opts: &CompileOptions) {
    let r = compile(w.name, w.src, opts).unwrap();
    let label = format!("{} ({:?})", w.name, opts.dae);

    assert_eq!(
        run_oracle(w, &r, JitConfig::forced(0)),
        run_oracle(w, &r, JitConfig::disabled()),
        "{label}: oracle jit-vs-interpreter"
    );
    assert_eq!(
        run_explicit(w, &r, JitConfig::forced(0)),
        run_explicit(w, &r, JitConfig::disabled()),
        "{label}: explicit jit-vs-interpreter"
    );

    let kernels = Arc::new(compile_module(&r.explicit, KernelMode::Explicit).unwrap());
    for workers in [1usize, 4] {
        assert_eq!(
            run_ws(w, &r, &kernels, JitConfig::forced(0), workers),
            run_ws(w, &r, &kernels, JitConfig::disabled(), workers),
            "{label}: ws jit-vs-interpreter (workers={workers})"
        );
    }
}

#[test]
fn jit_vs_interpreter_differential_no_dae() {
    let opts = CompileOptions::no_dae();
    for w in corpus() {
        check_jit_differential(&w, &opts);
    }
}

#[test]
fn jit_vs_interpreter_differential_dae() {
    let opts = CompileOptions::standard();
    for w in corpus() {
        check_jit_differential(&w, &opts);
    }
}

// ---------------------------------------------------------------------------
// Bailout: mixed int/float frames hand back to the interpreter mid-frame

/// Fib-shaped traversal whose leaves touch a float global: the leaf
/// branch's float load/store can't live in the int slot arena, so a
/// natively-entered leaf activation must bail and resume interpreted —
/// while the recursive branch keeps running natively.
const MIX_SRC: &str = "\
global float acc[4];

int mix(int n) {
    if (n < 2) {
        float t = acc[0];
        acc[0] = t + 0.5;
        return n;
    }
    int x = cilk_spawn mix(n - 1);
    int y = cilk_spawn mix(n - 2);
    cilk_sync;
    return x + y;
}
";

#[test]
fn bailout_hands_mixed_float_frames_back_to_the_interpreter() {
    for opts in [CompileOptions::no_dae(), CompileOptions::standard()] {
        let r = compile("mix", MIX_SRC, &opts).unwrap();
        let m = &r.explicit;
        let kernels = Arc::new(compile_module(m, KernelMode::Explicit).unwrap());
        // The interned JitProgram (and its entry/bail counters) lives as
        // long as some tier over it does — hold one across the runs so
        // stats_for still sees the counters after the engines drop.
        let _pin = jit::tier_with(&kernels, JitConfig::forced(0));
        let run = |cfg: JitConfig| {
            let mut ex = ExplicitExec::with_kernels(m, Memory::new(m), NoXla, Arc::clone(&kernels));
            ex.set_jit(cfg);
            let v = ex.run("mix", &[Value::I64(10)]).unwrap();
            (v.as_i64(), memory_image(m, &ex.memory), ex.stats.tasks_run, ex.stats.closures_made)
        };
        let jit = run(JitConfig::forced(0));
        let interp = run(JitConfig::disabled());
        assert_eq!(jit, interp, "mix ({:?}): bailing runs must match the interpreter", opts.dae);
        assert_eq!(jit.0, 55, "mix(10) returns fib(10)");

        if jit::available().is_ok() {
            let stats = jit::stats_for(&kernels);
            let entries: u64 = stats.iter().map(|s| s.entries).sum();
            let bails: u64 = stats.iter().map(|s| s.bails).sum();
            assert!(entries > 0, "mix ({:?}): forced tier must enter native code", opts.dae);
            assert!(bails > 0, "mix ({:?}): float leaves must bail", opts.dae);
            assert!(bails <= entries, "mix ({:?}): bails are a subset of entries", opts.dae);
        }
    }
}

// ---------------------------------------------------------------------------
// Tier promotion determinism

#[test]
fn tier_promotion_is_deterministic_across_worker_counts() {
    // Whether a dispatch runs interpreted (below threshold) or natively
    // must never change results or the deterministic counters — at any
    // threshold, any worker count.
    let w = Workload {
        name: "fib",
        src: fib::FIB_SRC,
        entry: "fib",
        args: vec![Value::I64(16)],
        init: Box::new(|_, _| {}),
        uses_xla: false,
    };
    let r = compile(w.name, w.src, &CompileOptions::no_dae()).unwrap();
    let kernels = Arc::new(compile_module(&r.explicit, KernelMode::Explicit).unwrap());
    let baseline = run_ws(&w, &r, &kernels, JitConfig::disabled(), 1);
    assert_eq!(baseline.0, fib::fib_ref(16) as i64);
    for workers in [1usize, 4] {
        for threshold in [0u64, 32] {
            assert_eq!(
                run_ws(&w, &r, &kernels, JitConfig::forced(threshold), workers),
                baseline,
                "fib: promotion at threshold {threshold} (workers={workers})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Native entry smoke + availability probe

#[test]
fn forced_tier_actually_enters_native_code_on_fib() {
    if jit::available().is_err() {
        return; // covered by the availability test below
    }
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let kernels = Arc::new(compile_module(&r.explicit, KernelMode::Explicit).unwrap());
    // Keep the interned JitProgram alive past the engine so its flushed
    // counters are still readable below.
    let _pin = jit::tier_with(&kernels, JitConfig::forced(0));
    let mut ex =
        ExplicitExec::with_kernels(&r.explicit, Memory::new(&r.explicit), NoXla, Arc::clone(&kernels));
    ex.set_jit(JitConfig::forced(0));
    let v = ex.run("fib", &[Value::I64(12)]).unwrap();
    assert_eq!(v.as_i64(), 144);
    drop(ex); // flush the tier's dispatch counters
    let stats = jit::stats_for(&kernels);
    let entries: u64 = stats.iter().map(|s| s.entries).sum();
    let dispatches: u64 = stats.iter().map(|s| s.dispatches).sum();
    assert!(entries > 0, "forced tier must enter native code");
    assert!(dispatches >= entries, "every native entry was a dispatch");
    assert!(
        stats.iter().any(|s| s.code_bytes > 0),
        "at least one kernel must have compiled machine code"
    );
}

#[test]
fn availability_probe_never_panics_and_disabled_config_stays_interpreted() {
    // The probe must resolve to a stable Ok or a reasoned error — never
    // a panic — and a disabled config must never hand out a tier even
    // where native codegen works.
    match jit::available() {
        Ok(()) => assert!(jit::disabled_reason().is_none()),
        Err(reason) => {
            assert!(reason.starts_with("jit:"), "disabled reason must be prefixed: {reason}");
            assert_eq!(jit::disabled_reason(), Some(reason));
        }
    }
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let kernels = Arc::new(compile_module(&r.explicit, KernelMode::Explicit).unwrap());
    assert!(
        jit::tier_with(&kernels, JitConfig::disabled()).is_none(),
        "disabled config must stay interpreted"
    );
    assert!(
        jit::tier_with(&kernels, JitConfig::forced(0)).is_some() == jit::available().is_ok(),
        "forced config hands out a tier exactly when native codegen is available"
    );
}
