//! Integration tests: the compile pipeline on the full workloads —
//! verifier cleanliness, task/path structure, closure layout rules.

use bombyx::ir::explicit::{closure_layout, explicit_tasks, MIN_CLOSURE_BITS};
use bombyx::ir::verify::{verify_module, Stage};
use bombyx::ir::TaskRole;
use bombyx::lower::{compile, CompileOptions};
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

const ALL: &[(&str, &str)] = &[
    ("fib", fib::FIB_SRC),
    ("bfs", bfs::BFS_SRC),
    ("bfs_dae", bfs::BFS_DAE_SRC),
    ("nqueens", nqueens::NQUEENS_SRC),
    ("qsort", qsort::QSORT_SRC),
    ("relax", relax::RELAX_SRC),
];

#[test]
fn every_workload_compiles_clean_through_both_stages() {
    for (name, src) in ALL {
        for opts in [CompileOptions::no_dae(), CompileOptions::standard()] {
            let r = compile(name, src, &opts).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(verify_module(&r.implicit, Stage::Implicit).is_empty(), "{name}");
            assert!(verify_module(&r.implicit_dae, Stage::Implicit).is_empty(), "{name}");
            assert!(verify_module(&r.explicit, Stage::Explicit).is_empty(), "{name}");
        }
    }
}

#[test]
fn closure_layouts_respect_hardware_rules() {
    for (name, src) in ALL {
        let r = compile(name, src, &CompileOptions::standard()).unwrap();
        for fid in explicit_tasks(&r.explicit) {
            let f = &r.explicit.funcs[fid];
            let l = closure_layout(f);
            assert!(l.padded_bits.is_power_of_two(), "{name}/{}", f.name);
            assert!(l.padded_bits >= MIN_CLOSURE_BITS, "{name}/{}", f.name);
            assert!(l.payload_bits <= l.padded_bits, "{name}/{}", f.name);
            // Fields are in-bounds, non-overlapping, 32-bit aligned.
            let mut last_end = 0;
            for field in &l.fields {
                assert_eq!(field.offset_bits % 32, 0, "{name}/{}", f.name);
                assert!(field.offset_bits >= last_end, "{name}/{}", f.name);
                last_end = field.offset_bits + field.width_bits;
            }
            assert!(last_end <= l.cont_offset_bits, "{name}/{}", f.name);
        }
    }
}

#[test]
fn dae_produces_the_paper_pe_trio() {
    let r = compile("bfs", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    let roles: Vec<(String, TaskRole)> = explicit_tasks(&r.explicit)
        .into_iter()
        .map(|fid| {
            let f = &r.explicit.funcs[fid];
            (f.name.clone(), f.task.as_ref().unwrap().role)
        })
        .collect();
    let count = |role: TaskRole| roles.iter().filter(|(_, r)| *r == role).count();
    assert_eq!(count(TaskRole::Entry), 1, "{roles:?}"); // spawner
    assert_eq!(count(TaskRole::Access), 1, "{roles:?}"); // access PE
    assert!(count(TaskRole::Continuation) >= 2, "{roles:?}"); // executor + notifier
}

#[test]
fn non_dae_compilation_ignores_pragma() {
    let with = compile("bfs", bfs::BFS_DAE_SRC, &CompileOptions::no_dae()).unwrap();
    let without = compile("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    assert_eq!(
        explicit_tasks(&with.explicit).len(),
        explicit_tasks(&without.explicit).len(),
        "pragma must be inert when DAE is off"
    );
}

#[test]
fn task_names_are_unique_and_stable() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let names: Vec<String> =
        r.explicit.funcs.values().map(|f| f.name.clone()).collect();
    let mut dedup = names.clone();
    dedup.sort();
    dedup.dedup();
    assert_eq!(dedup.len(), names.len(), "{names:?}");
    // Recompiling yields the same names in the same order.
    let r2 = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let names2: Vec<String> = r2.explicit.funcs.values().map(|f| f.name.clone()).collect();
    assert_eq!(names, names2);
}
