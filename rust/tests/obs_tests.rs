//! Telemetry-layer integration tests: trace integrity (determinism,
//! B/E balance, job→task nesting), metrics schema stability, executor
//! total/metric parity, the retired-fast-path grep pin, and CI artifact
//! validation (`BOMBYX_OBS_TRACE_FILE` / `BOMBYX_OBS_METRICS_FILE`).
//!
//! The obs layer is process-global state, so every test that arms it
//! serializes on [`OBS_LOCK`] and starts/ends from `obs::reset_all()`.

use std::sync::Mutex;

use bombyx::coordinator::WsServeExperiment;
use bombyx::ir::expr::Value;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::obs;
use bombyx::util::json::{self, Json};
use bombyx::workloads::fib;
use bombyx::ws::{self, WsConfig};

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn num(v: &Json) -> f64 {
    match v {
        Json::Int(i) => *i as f64,
        Json::Float(f) => *f,
        other => panic!("expected a number, got {other:?}"),
    }
}

/// The direct-threaded retired dispatch loop must stay telemetry-free:
/// tracing/metrics/profiling hook the once-per-frame `on_dispatch` seam,
/// never the per-instruction path. This pins the marked region of
/// `exec_frame` by text so an instrumented hot loop fails CI.
#[test]
fn retired_fast_path_has_no_telemetry() {
    let src = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src/exec/kernel.rs");
    let text = std::fs::read_to_string(src).expect("read kernel.rs");
    let begin = text
        .find("RETIRED_FAST_PATH_BEGIN")
        .expect("kernel.rs must keep the RETIRED_FAST_PATH_BEGIN marker");
    let end = text
        .find("RETIRED_FAST_PATH_END")
        .expect("kernel.rs must keep the RETIRED_FAST_PATH_END marker");
    assert!(begin < end, "markers out of order");
    let region = &text[begin..end];
    assert!(
        region.contains("table[instr.h as usize]"),
        "marked region must still contain the direct-threaded dispatch"
    );
    for banned in ["obs::", "profile::hit", "counter_add", "observe", "trace::", "gauge_set"] {
        assert!(
            !region.contains(banned),
            "telemetry call `{banned}` found inside the retired dispatch loop"
        );
    }
}

fn single_worker_task_spans() -> Vec<(&'static str, String)> {
    let session =
        CompileSession::new("obs_fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    obs::set_trace(true);
    let cfg = WsConfig { workers: 1, steal_tries: 4 };
    let (v, _, _) = session
        .run_ws(session.shared_memory(), "fib", &[Value::I64(10)], &cfg, Box::new(ws::NoXlaSink))
        .unwrap();
    assert_eq!(v.as_i64(), fib::fib_ref(10) as i64);
    obs::set_trace(false);
    let events = obs::trace::drain();
    events
        .iter()
        .filter(|e| e.cat == "task" && (e.ph == "B" || e.ph == "E"))
        .map(|e| (e.ph, e.name.to_string()))
        .collect()
}

/// One worker ⇒ no steals ⇒ the task span tree is a pure function of the
/// program: two runs must record the identical (ph, name) sequence.
#[test]
fn single_worker_trace_is_deterministic() {
    let _g = lock();
    obs::reset_all();
    let a = single_worker_task_spans();
    obs::reset_all();
    let b = single_worker_task_spans();
    obs::reset_all();
    assert!(!a.is_empty(), "a 1-worker fib(10) run must record task spans");
    assert_eq!(a, b, "1-worker task span tree must be deterministic");
}

/// 4-worker 32-job flood: the exported document round-trips through
/// `util::json`, every `E` closes the matching `B` on its own tid, job
/// async spans contain their task-dispatch children, and `summarize`
/// sees a balanced trace with all 32 jobs.
#[test]
fn flood_trace_round_trips_and_nests() {
    let _g = lock();
    obs::reset_all();
    let exp = WsServeExperiment::new().unwrap();
    obs::set_trace(true);
    let report = exp.flood(4, 32, 1).unwrap();
    obs::set_trace(false);
    assert_eq!(report.verified, 32);
    let events = obs::trace::drain();
    obs::reset_all();

    let doc = obs::trace::export_json(&events);
    let text = doc.pretty();
    let parsed = json::parse(&text).expect("trace export must be valid JSON");
    let rows = parsed
        .get("traceEvents")
        .and_then(|v| v.as_array())
        .expect("traceEvents array");
    assert_eq!(rows.len(), events.len());

    // B/E balance: per-tid LIFO matching, nothing left open.
    let mut stacks: std::collections::BTreeMap<i64, Vec<String>> = Default::default();
    // Job windows (async spans, cat "job"): id -> (b_ts, e_ts).
    let mut begins: std::collections::BTreeMap<i64, f64> = Default::default();
    let mut windows: std::collections::BTreeMap<i64, (f64, f64)> = Default::default();
    let mut task_children: Vec<(i64, f64)> = Vec::new(); // (job id, B ts)
    for ev in rows {
        let ph = ev.get("ph").and_then(|v| v.as_str()).expect("ph");
        let name = ev.get("name").and_then(|v| v.as_str()).expect("name").to_string();
        let cat = ev.get("cat").and_then(|v| v.as_str()).expect("cat");
        let tid = ev.get("tid").and_then(|v| v.as_i64()).expect("tid");
        let ts = num(ev.get("ts").expect("ts"));
        assert!(ts.is_finite(), "non-finite ts on `{name}`");
        match ph {
            "B" => {
                stacks.entry(tid).or_default().push(name.clone());
                if cat == "task" {
                    let job = ev
                        .get("args")
                        .and_then(|a| a.get("job"))
                        .and_then(|v| v.as_i64())
                        .expect("task span must carry its job id");
                    task_children.push((job, ts));
                }
            }
            "E" => {
                let open = stacks.entry(tid).or_default().pop();
                assert_eq!(
                    open.as_deref(),
                    Some(name.as_str()),
                    "E `{name}` must close the innermost B on tid {tid}"
                );
            }
            "b" if cat == "job" => {
                let id = ev.get("id").and_then(|v| v.as_i64()).expect("async id");
                begins.insert(id, ts);
            }
            "e" if cat == "job" => {
                let id = ev.get("id").and_then(|v| v.as_i64()).expect("async id");
                let t0 = begins.remove(&id).expect("job `e` without `b`");
                windows.insert(id, (t0, ts));
            }
            _ => {}
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "unclosed B span(s) {stack:?} on tid {tid}");
    }
    assert!(begins.is_empty(), "job span(s) never closed: {begins:?}");
    assert_eq!(windows.len(), 32, "one async job span per flooded job");
    assert!(!task_children.is_empty(), "flood must record task dispatch spans");
    for (job, ts) in &task_children {
        let (t0, t1) = windows
            .get(job)
            .unwrap_or_else(|| panic!("task span references unknown job {job}"));
        assert!(
            *ts >= *t0 && *ts <= *t1,
            "task dispatch at {ts} outside job {job} window [{t0}, {t1}]"
        );
    }

    let summary = obs::trace::summarize(&parsed).expect("summarize");
    assert_eq!(summary.unbalanced, 0, "summarize must see a balanced trace");
    assert_eq!(summary.jobs.len(), 32);
    for (_, _, latency_ms, milestones) in &summary.jobs {
        assert!(latency_ms.is_finite() && *latency_ms >= 0.0);
        assert!(
            milestones.iter().any(|m| m == "admit" || m == "queue"),
            "every job passes an admission milestone, got {milestones:?}"
        );
    }
}

/// The `bombyx-metrics-v1` document: schema tag present, executor totals
/// mirrored as counters, latency histogram finite with ordered
/// percentiles — and it round-trips through `util::json`.
#[test]
fn flood_metrics_schema_is_stable() {
    let _g = lock();
    obs::reset_all();
    let exp = WsServeExperiment::new().unwrap();
    obs::set_metrics(true);
    let report = exp.flood(4, 8, 1).unwrap();
    obs::set_metrics(false);
    let doc = obs::metrics::export_json();
    obs::reset_all();
    assert_eq!(report.verified, 8);

    let text = doc.pretty();
    let parsed = json::parse(&text).expect("metrics export must be valid JSON");
    assert_eq!(
        parsed.get("schema").and_then(|v| v.as_str()),
        Some(obs::metrics::SCHEMA),
        "schema tag must be stable"
    );
    let counters = parsed.get("counters").expect("counters object");
    let counter = |name: &str| {
        counters
            .get(name)
            .and_then(|v| v.as_i64())
            .unwrap_or_else(|| panic!("missing counter `{name}`"))
    };
    assert_eq!(counter("ws.jobs_submitted"), 8);
    assert_eq!(counter("ws.jobs_completed"), 8);
    assert_eq!(counter("ws.jobs_failed"), 0);
    assert_eq!(counter("ws.jobs_cancelled"), 0);
    assert!(counter("ws.tasks_run") > 0);
    // Totals published by `Executor::publish_metrics` match the stats
    // struct the flood report carries.
    assert_eq!(counter("ws.tasks_run") as u64, report.stats.tasks_run);
    assert_eq!(counter("ws.instrs_retired") as u64, report.stats.instrs);

    let hist = parsed
        .get("histograms")
        .and_then(|h| h.get("ws.job.latency_ms"))
        .expect("job latency histogram");
    assert_eq!(hist.get("count").and_then(|v| v.as_i64()), Some(8));
    let p50 = num(hist.get("p50").expect("p50"));
    let p95 = num(hist.get("p95").expect("p95"));
    let p99 = num(hist.get("p99").expect("p99"));
    for v in [p50, p95, p99] {
        assert!(v.is_finite() && v >= 0.0, "percentiles must be finite, got {v}");
    }
    assert!(p50 <= p95 && p95 <= p99, "percentiles out of order: {p50} {p95} {p99}");
}

/// Satellite 2: terminal classification is exactly-once. A cancel after
/// delivery must not double-count, and however a submit/drop race lands,
/// every submitted job ends in exactly one terminal class.
#[test]
fn executor_totals_classify_every_job_once() {
    let _g = lock();
    obs::reset_all();
    let exp = WsServeExperiment::new().unwrap();

    // Cancel after completion: stays completed.
    let executor = ws::Executor::new(ws::ExecutorConfig::default()).unwrap();
    let handle = executor.submit(exp.job(0).unwrap()).unwrap();
    handle.wait();
    handle.cancel();
    handle.cancel(); // idempotent
    let stats = executor.stats();
    drop(executor);
    assert_eq!(stats.jobs_submitted, 1);
    assert_eq!(stats.jobs_completed, 1);
    assert_eq!(stats.jobs_cancelled, 0, "cancel after delivery must not reclassify");
    assert_eq!(stats.jobs_failed, 0);

    // Exercise the Drop path with a job possibly still in flight: the
    // executor must classify leftovers through `fail_job` (not slip them
    // past `complete`) and shut down cleanly either way.
    let executor = ws::Executor::new(ws::ExecutorConfig::default()).unwrap();
    let _in_flight = executor.submit(exp.job(1).unwrap()).unwrap();
    drop(executor);

    let executor = ws::Executor::new(ws::ExecutorConfig::default()).unwrap();
    let h1 = executor.submit(exp.job(1).unwrap()).unwrap();
    let h2 = executor.submit(exp.job(2).unwrap()).unwrap();
    h1.wait();
    h2.wait();
    let stats = executor.stats();
    drop(executor);
    assert_eq!(
        stats.jobs_completed + stats.jobs_failed + stats.jobs_cancelled,
        stats.jobs_submitted,
        "every job must land in exactly one terminal class"
    );
    obs::reset_all();
}

/// Telemetry fully disabled must record nothing — the overhead contract
/// (`rust/src/obs/README.md`) starts with "off means off".
#[test]
fn disabled_obs_records_nothing() {
    let _g = lock();
    obs::reset_all();
    let session =
        CompileSession::new("obs_off", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let cfg = WsConfig { workers: 2, steal_tries: 4 };
    let (v, _, _) = session
        .run_ws(session.shared_memory(), "fib", &[Value::I64(12)], &cfg, Box::new(ws::NoXlaSink))
        .unwrap();
    assert_eq!(v.as_i64(), fib::fib_ref(12) as i64);
    assert!(obs::trace::drain().is_empty(), "disabled tracing must record no events");
    assert!(obs::profile::snapshot().is_empty(), "disabled profiler must record no hits");
    let doc = obs::metrics::export_json();
    match doc.get("counters") {
        Some(Json::Object(map)) => {
            assert!(map.is_empty(), "disabled metrics must record no counters: {}", doc.pretty())
        }
        other => panic!("counters must be an object, got {other:?}"),
    }
    obs::reset_all();
}

/// CI artifact gate: when the bench-smoke step exports
/// `TRACE_smoke.json` / `METRICS_smoke.json`, point
/// `BOMBYX_OBS_TRACE_FILE` / `BOMBYX_OBS_METRICS_FILE` here to
/// schema-validate them. Without the env vars this test is a no-op.
#[test]
fn ci_artifacts_validate() {
    if let Ok(path) = std::env::var("BOMBYX_OBS_TRACE_FILE") {
        let text = std::fs::read_to_string(&path).expect("read trace artifact");
        let doc = json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        let summary = obs::trace::summarize(&doc).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(summary.unbalanced, 0, "{path}: unbalanced spans");
        assert!(!summary.jobs.is_empty(), "{path}: no job spans in the smoke trace");
    }
    if let Ok(path) = std::env::var("BOMBYX_OBS_METRICS_FILE") {
        let text = std::fs::read_to_string(&path).expect("read metrics artifact");
        let doc = json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(
            doc.get("schema").and_then(|v| v.as_str()),
            Some(obs::metrics::SCHEMA),
            "{path}: wrong schema tag"
        );
        for section in ["counters", "gauges", "histograms"] {
            assert!(doc.get(section).is_some(), "{path}: missing `{section}`");
        }
    }
}
