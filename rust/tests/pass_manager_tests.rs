//! Integration tests for the lowering pass manager and the multi-target
//! compile session: ordering enforcement, inter-pass verification, timing
//! counters, and per-target artifact memoization.

use bombyx::frontend::parse_and_check;
use bombyx::interp::Memory;
use bombyx::ir::cfg::Term;
use bombyx::ir::print::print_module;
use bombyx::ir::{BlockId, Value};
use bombyx::lower::pass::{Artifact, Explicitize, Pass, PassManager, PipelineStage};
use bombyx::lower::{compile, CompileOptions, CompileSession};
use bombyx::sim::{NoSimXla, SimConfig};
use bombyx::ws::{NoXlaSink, WsConfig};

const FIB: &str = "int fib(int n) {
    if (n < 2) return n;
    int x = cilk_spawn fib(n - 1);
    int y = cilk_spawn fib(n - 2);
    cilk_sync;
    return x + y;
}";

#[test]
fn standard_pipeline_reports_per_pass_timings() {
    let r = compile("fib", FIB, &CompileOptions::standard()).unwrap();
    let names: Vec<&str> = r.timings.iter().map(|t| t.pass).collect();
    assert_eq!(
        names,
        vec!["ast_to_cfg", "simplify", "dae", "simplify_post_dae", "explicitize"]
    );
    assert!(r.timings.iter().all(|t| t.ran), "{:?}", r.timings);
}

#[test]
fn disabled_passes_are_reported_as_skipped() {
    let r = compile("fib", FIB, &CompileOptions::no_dae()).unwrap();
    let dae = r.timings.iter().find(|t| t.pass == "dae").unwrap();
    assert!(!dae.ran, "dae must be skipped under no_dae options");
}

#[test]
fn pass_ordering_is_enforced() {
    // Explicitize fed an un-lowered AST: the manager rejects it before the
    // pass runs.
    let (program, _) = parse_and_check("t", FIB).unwrap();
    let manager = PassManager::new().add(Explicitize);
    let err = manager
        .run(Artifact::Ast(program), &CompileOptions::standard(), |_, _| {})
        .unwrap_err();
    assert!(err.to_string().contains("pass ordering violation"), "{err}");
}

#[test]
fn explicitize_rejects_unlowered_input() {
    let (program, _) = parse_and_check("t", FIB).unwrap();
    let err = Explicitize
        .run(Artifact::Ast(program), &CompileOptions::standard())
        .unwrap_err();
    assert!(err.to_string().contains("unlowered AST"), "{err}");
}

#[test]
fn interpass_verification_catches_a_corrupted_cfg() {
    struct CorruptTerminator;
    impl Pass for CorruptTerminator {
        fn name(&self) -> &'static str {
            "corrupt_terminator"
        }
        fn input_stage(&self) -> PipelineStage {
            PipelineStage::Implicit
        }
        fn output_stage(&self) -> PipelineStage {
            PipelineStage::Implicit
        }
        fn run(
            &self,
            artifact: Artifact,
            _opts: &CompileOptions,
        ) -> anyhow::Result<Artifact> {
            let mut module = artifact.into_module()?;
            let m = std::sync::Arc::make_mut(&mut module);
            let (_, func) = m.funcs.iter_mut().next().expect("one function");
            let entry = func.cfg().entry;
            func.cfg_mut().blocks[entry].term = Term::Jump(BlockId::new(9_999));
            Ok(Artifact::Module(module))
        }
    }
    let r = compile("fib", FIB, &CompileOptions::no_dae()).unwrap();
    let manager = PassManager::new().add(CorruptTerminator);
    let err = manager
        .run(Artifact::Module(r.implicit.clone()), &CompileOptions::no_dae(), |_, _| {})
        .unwrap_err();
    let text = err.to_string();
    assert!(text.contains("corrupt_terminator"), "{text}");
    assert!(text.contains("post-verification"), "{text}");
    assert!(text.contains("nonexistent"), "{text}");
}

#[test]
fn compile_session_memoizes_target_artifacts() {
    let mut session = CompileSession::new("fib", FIB, &CompileOptions::no_dae()).unwrap();
    let explicit_before = print_module(session.explicit());

    let emu1: *const bombyx::backend::emu::EmuProgram = session.emu_program();
    let emu2: *const bombyx::backend::emu::EmuProgram = session.emu_program();
    assert_eq!(emu1, emu2, "emu program must be packaged once and cached");

    let sys1: *const bombyx::backend::hardcilk::HardCilkSystem =
        session.hardcilk_system("sys").unwrap();
    let sys2: *const bombyx::backend::hardcilk::HardCilkSystem =
        session.hardcilk_system("sys").unwrap();
    assert_eq!(sys1, sys2, "hardcilk system must be generated once per name");

    // Repeated target requests never re-lower: the shared explicit module
    // is bit-identical, and the emu packaging wraps that same module.
    assert_eq!(print_module(session.explicit()), explicit_before);
    assert_eq!(print_module(&session.emu_program().module), explicit_before);
}

#[test]
fn session_targets_agree_on_the_cached_module() {
    let session = CompileSession::new("fib", FIB, &CompileOptions::no_dae()).unwrap();
    let args = [Value::I64(10)];
    let (v_oracle, _) =
        session.run_oracle(Memory::new(session.implicit()), "fib", &args).unwrap();
    let (v_explicit, _) = session.run_explicit(session.memory(), "fib", &args).unwrap();
    let (v_sim, _, _) = session
        .simulate(session.memory(), "fib", &args, &SimConfig::default(), &mut NoSimXla)
        .unwrap();
    let (v_ws, _, _) = session
        .run_ws(
            session.shared_memory(),
            "fib",
            &args,
            &WsConfig { workers: 2, steal_tries: 2 },
            Box::new(NoXlaSink),
        )
        .unwrap();
    assert_eq!(v_oracle.as_i64(), 55);
    assert_eq!(v_explicit.as_i64(), 55);
    assert_eq!(v_sim.as_i64(), 55);
    assert_eq!(v_ws.as_i64(), 55);
}
