//! Property-based tests (mini harness from `util::prop`): random workloads
//! and parameters through the full stack, checking the invariants DESIGN.md
//! §6 calls out.

use bombyx::interp::explicit_exec::{ExplicitExec, Order};
use bombyx::interp::{oracle, Memory, NoXla};
use bombyx::ir::explicit::closure_layout;
use bombyx::ir::{Module, Value};
use bombyx::lower::{compile, CompileOptions};
use bombyx::prop_assert;
use bombyx::sim::{simulate, NoSimXla, SimConfig};
use bombyx::util::prop::prop_check;
use bombyx::workloads::{bfs, graphgen, qsort};

#[test]
fn prop_random_dags_bfs_all_engines_agree() {
    let plain = compile("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    let dae = compile("bfs", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    prop_check("bfs dag equivalence", 25, |g| {
        let nodes = g.usize_in(2, 120);
        let seed = g.u64_below(1 << 40);
        // Trees only: sim functional reads are dispatch-time (DESIGN.md),
        // so shared children could legally be visited twice under racy
        // schedules. Trees are the paper's dataset and race-free.
        let depth = g.usize_in(1, 5) as u32;
        let branch = g.usize_in(1, 4) as u64;
        let _ = nodes;
        let graph = graphgen::tree(branch, depth);
        let _ = seed;

        let mut visiteds = Vec::new();
        for r in [&plain, &dae] {
            let m = &r.explicit;
            let mut mem = Memory::new(m);
            bfs::init_memory(m, &mut mem, &graph).map_err(|e| e.to_string())?;
            let (_, mem, _) = simulate(
                m,
                mem,
                "visit",
                &[Value::I64(0)],
                &SimConfig::default(),
                &mut NoSimXla,
            )
            .map_err(|e| e.to_string())?;
            visiteds.push(mem.dump_i64(m.global_by_name("visited").unwrap()));
        }
        prop_assert!(
            visiteds[0] == visiteds[1],
            "DAE changed traversal on tree B={branch} D={depth}"
        );
        prop_assert!(
            visiteds[0].iter().all(|&v| v == 1),
            "unvisited nodes on tree B={branch} D={depth}"
        );
        Ok(())
    });
}

#[test]
fn prop_qsort_random_arrays_explicit_machine() {
    let r = compile("qs", qsort::QSORT_SRC, &CompileOptions::no_dae()).unwrap();
    prop_check("qsort sorts", 40, |g| {
        let len = g.usize_in(1, 200);
        let input: Vec<i64> = (0..len).map(|_| g.i64_in(-1000, 1000)).collect();
        let mut mem = Memory::new(&r.explicit);
        mem.fill_i64(r.explicit.global_by_name("data").unwrap(), &input);
        let mut ex = ExplicitExec::new(&r.explicit, mem, NoXla);
        ex.order = if g.bool() { Order::Lifo } else { Order::Fifo };
        ex.run("qsort_", &[Value::I64(0), Value::I64(len as i64 - 1)])
            .map_err(|e| e.to_string())?;
        let mut expect = input.clone();
        expect.sort();
        let got = ex.memory.dump_i64(r.explicit.global_by_name("data").unwrap());
        prop_assert!(got == expect, "len {len}: {got:?} != {expect:?}");
        prop_assert!(ex.live_closures() == 0, "closure leak");
        Ok(())
    });
}

#[test]
fn prop_random_fib_like_programs_compile_and_agree() {
    // Generate tiny random spawn/sync programs with a parametric shape:
    // f(n) spawns g(n-1) a..b times (void) and accumulates via memory.
    prop_check("random spawn programs", 30, |g| {
        let spawns = g.usize_in(1, 3);
        let depth_bound = g.usize_in(1, 6);
        let weight = g.i64_in(1, 5);
        let spawn_lines: String = (0..spawns)
            .map(|_| "    cilk_spawn f(n - 1);\n".to_string())
            .collect();
        let src = format!(
            "global int acc[1];
             void f(int n) {{
                 if (n <= 0) {{
                     atomic_add(acc, 0, {weight});
                     return;
                 }}
                 {spawn_lines}
                 cilk_sync;
             }}"
        );
        let r = compile("gen", &src, &CompileOptions::no_dae()).map_err(|e| e.to_string())?;

        let run_oracle_val = |m: &Module| -> Result<i64, String> {
            let (_, mem) = oracle::run_oracle(
                &r.implicit,
                Memory::new(m),
                "f",
                &[Value::I64(depth_bound as i64)],
            )
            .map_err(|e| e.to_string())?;
            Ok(mem.dump_i64(m.global_by_name("acc").unwrap())[0])
        };
        let expected = run_oracle_val(&r.implicit)?;
        // leaves = spawns^depth, each adds `weight`.
        let leaves = (spawns as i64).pow(depth_bound as u32);
        prop_assert!(
            expected == leaves * weight,
            "oracle {expected} != closed form {}",
            leaves * weight
        );

        let mut ex = ExplicitExec::new(&r.explicit, Memory::new(&r.explicit), NoXla);
        ex.run("f", &[Value::I64(depth_bound as i64)]).map_err(|e| e.to_string())?;
        let got = ex.memory.dump_i64(r.explicit.global_by_name("acc").unwrap())[0];
        prop_assert!(got == expected, "explicit {got} != oracle {expected}");
        Ok(())
    });
}

#[test]
fn prop_closure_layouts_always_legal() {
    // Random signatures → layout invariants (alignment, bounds, pow2).
    use bombyx::frontend::ast::Type;
    use bombyx::ir::cfg::{Func, FuncKind};
    use bombyx::ir::expr::Var;
    use bombyx::util::idvec::IdVec;
    prop_check("closure layout legal", 200, |g| {
        let nparams = g.usize_in(0, 12);
        let mut vars = IdVec::new();
        for i in 0..nparams {
            let ty = *g.pick(&[Type::Int, Type::Float, Type::Bool]);
            vars.push(Var { name: format!("p{i}"), ty, is_param: true, is_temp: false });
        }
        let f = Func {
            name: "t".into(),
            ret: Type::Int,
            params: nparams,
            vars,
            body: None,
            kind: FuncKind::Task,
            task: None,
        };
        let l = closure_layout(&f);
        prop_assert!(l.padded_bits.is_power_of_two(), "pow2: {}", l.padded_bits);
        prop_assert!(l.payload_bits <= l.padded_bits, "payload fits");
        prop_assert!(l.cont_offset_bits % 64 == 0, "cont aligned");
        for w in l.fields.windows(2) {
            prop_assert!(
                w[0].offset_bits + w[0].width_bits <= w[1].offset_bits,
                "fields overlap"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sim_cycles_deterministic_across_configs() {
    let r = compile("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    prop_check("sim determinism", 10, |g| {
        let depth = g.usize_in(2, 5) as u32;
        let graph = graphgen::tree(3, depth);
        let mut cfg = SimConfig::default();
        cfg.mem_latency = g.usize_in(5, 200) as u32;
        cfg.default_pes = g.usize_in(1, 8) as u32;
        let run = || -> Result<u64, String> {
            let m = &r.explicit;
            let mut mem = Memory::new(m);
            bfs::init_memory(m, &mut mem, &graph).map_err(|e| e.to_string())?;
            Ok(simulate(m, mem, "visit", &[Value::I64(0)], &cfg, &mut NoSimXla)
                .map_err(|e| e.to_string())?
                .2
                .cycles)
        };
        let a = run()?;
        let b = run()?;
        prop_assert!(a == b, "nondeterministic: {a} vs {b}");
        Ok(())
    });
}
