//! Integration tests for the RTL backend: golden Verilog systems for
//! `fib`/`bfs_dae`, the structural lint over every workload, the II=1
//! pipelined access PE, and `CompileSession::rtl_system` memoization.

use bombyx::backend::rtl::{self, PeStyle};
use bombyx::lower::{compile, CompileOptions, CompileSession};
use bombyx::util::golden::check_golden;
use bombyx::workloads::{bfs, fib, nqueens, qsort, relax};

const ALL: &[(&str, &str, bool)] = &[
    // (name, source, dae)
    ("fib", fib::FIB_SRC, false),
    ("bfs", bfs::BFS_SRC, false),
    ("bfs_dae", bfs::BFS_DAE_SRC, true),
    ("nqueens", nqueens::NQUEENS_SRC, false),
    ("qsort", qsort::QSORT_SRC, false),
    ("relax", relax::RELAX_SRC, false),
];

fn opts(dae: bool) -> CompileOptions {
    if dae {
        CompileOptions::standard()
    } else {
        CompileOptions::no_dae()
    }
}

#[test]
fn every_workload_generates_a_lint_clean_system() {
    for &(name, src, dae) in ALL {
        let r = compile(name, src, &opts(dae)).unwrap();
        let sys = rtl::generate(&r.explicit, name)
            .unwrap_or_else(|e| panic!("{name}: rtl generation failed: {e:#}"));
        assert!(!sys.pes.is_empty(), "{name}");
        let errors = sys.lint();
        assert!(errors.is_empty(), "{name}: lint errors:\n{errors:#?}");
        // Every PE module declares its clocked interface.
        for pe in &sys.pes {
            assert!(pe.source.contains("input  wire clk"), "{name}/{}", pe.task);
            assert!(pe.source.contains("task_in_valid"), "{name}/{}", pe.task);
        }
        // The wrapper instantiates one queue and one PE per task.
        for pe in &sys.pes {
            let t = pe.task.replace("__", "_k_");
            assert!(sys.top.contains(&format!("pe_{t} u_{t}")), "{name}: missing PE {t}");
            assert!(sys.top.contains(&format!("q_{t}")), "{name}: missing queue for {t}");
        }
    }
}

#[test]
fn golden_fib_system() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let sys = rtl::generate(&r.explicit, "fib_system").unwrap();
    check_golden("rust/tests/goldens/rtl/fib_system.v", &sys.concatenated());
}

#[test]
fn golden_bfs_dae_system() {
    let r = compile("bfs_dae", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    let sys = rtl::generate(&r.explicit, "bfs_dae_system").unwrap();
    check_golden("rust/tests/goldens/rtl/bfs_dae_system.v", &sys.concatenated());
}

#[test]
fn dae_access_pe_is_pipelined_at_ii_1() {
    let r = compile("bfs_dae", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    let sys = rtl::generate(&r.explicit, "bfs_dae_system").unwrap();
    let access = sys
        .pes
        .iter()
        .find(|pe| pe.task == "adj_off_access")
        .expect("access PE generated");
    assert_eq!(access.style, PeStyle::Pipelined { ii: 1 }, "{}", access.source);
    assert_eq!(access.role, "access");
    // The pipelined template: no FSM, an in-flight FIFO, single-cycle
    // accept coupling task_in to the memory request channel.
    assert_eq!(access.states, 0);
    assert!(access.source.contains("bx_fifo"), "{}", access.source);
    assert!(access.source.contains("II=1"), "{}", access.source);
    // The report surfaces the II for the CLI / acceptance check.
    assert!(sys.report().contains("II=1"), "{}", sys.report());
    // The executor keeps the FSM style (it cannot pipeline, §II-C).
    let exec = sys.pes.iter().find(|pe| pe.task == "visit__k1").expect("executor PE");
    assert_eq!(exec.style, PeStyle::Fsm);
    assert!(exec.states > 0);
}

#[test]
fn compile_session_memoizes_rtl_system() {
    let mut session =
        CompileSession::new("bfs_dae", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    let timings_before = session.timings().len();
    let first = session.rtl_system("sys").unwrap().concatenated();
    let timings_after_first = session.timings().len();
    assert!(
        timings_after_first > timings_before,
        "rtl_emit must be timed through the pass manager"
    );
    assert!(session.timings().iter().any(|t| t.pass == "rtl_emit" && t.ran));
    // Second request: same artifact, no new pass run.
    let second = session.rtl_system("sys").unwrap().concatenated();
    assert_eq!(first, second);
    assert_eq!(
        session.timings().len(),
        timings_after_first,
        "second rtl_system request must not re-lower"
    );
    // A different system name does emit again (memoized per name).
    let _ = session.rtl_system("sys2").unwrap();
    assert!(session.timings().len() > timings_after_first);
}

#[test]
fn fsm_pe_structure_is_sane() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let sys = rtl::generate(&r.explicit, "fib_system").unwrap();
    let entry = sys.pes.iter().find(|pe| pe.task == "fib").unwrap();
    // Spawns two children, allocates one continuation closure.
    assert!(entry.source.contains("spawn_out_valid"), "{}", entry.source);
    assert!(entry.source.contains("spawn_next_out_valid"), "{}", entry.source);
    assert!(entry.source.contains("S_IDLE"), "{}", entry.source);
    assert!(entry.source.contains("always @(posedge clk)"), "{}", entry.source);
    // Resource estimates are attached per module.
    assert!(entry.source.contains("est. resources: LUT="), "{}", entry.source);
    assert!(entry.resources.lut > 0);
    // The continuation sends x + y to its own continuation.
    let cont = sys.pes.iter().find(|pe| pe.task == "fib__k1").unwrap();
    assert!(cont.source.contains("send_out_valid"), "{}", cont.source);
}

#[test]
fn leaf_functions_become_modules_in_the_package() {
    let r = compile("qsort", qsort::QSORT_SRC, &CompileOptions::no_dae()).unwrap();
    let sys = rtl::generate(&r.explicit, "qsort_system").unwrap();
    assert!(
        sys.package.contains("module leaf_partition_ ("),
        "leaf module emitted:\n{}",
        sys.package
    );
    // The caller PE instantiates it and exports its memory port.
    let entry = sys.pes.iter().find(|pe| pe.task == "qsort_").unwrap();
    assert!(entry.source.contains("leaf_partition_ u_leaf0"), "{}", entry.source);
    assert!(entry.source.contains("l0_mem_data_req_valid"), "{}", entry.source);
}

#[test]
fn xla_task_becomes_blackbox_shell() {
    let r = compile("relax", relax::RELAX_SRC, &CompileOptions::no_dae()).unwrap();
    let sys = rtl::generate(&r.explicit, "relax_system").unwrap();
    let xla = sys.pes.iter().find(|pe| pe.task == "relax").unwrap();
    assert_eq!(xla.style, PeStyle::Blackbox);
    assert!(xla.source.contains("BLACKBOX"), "{}", xla.source);
}

#[test]
fn lint_catches_broken_verilog() {
    use bombyx::backend::rtl::lint::lint;
    // Unbalanced module.
    assert!(!lint("module m (\n  input wire clk\n);\n").is_empty());
    // Undeclared wire.
    let errs = lint("module m (\n  input wire a,\n  output wire y\n);\n  assign y = ghost_wire;\nendmodule\n");
    assert!(errs.iter().any(|e| e.contains("ghost_wire")), "{errs:?}");
    // Reg with two always-block drivers.
    let errs = lint(
        "module m (\n  input wire clk\n);\n  reg r;\n\
         always @(posedge clk) begin\n    r <= 1'b0;\n  end\n\
         always @(posedge clk) begin\n    r <= 1'b1;\n  end\nendmodule\n",
    );
    assert!(errs.iter().any(|e| e.contains("always blocks")), "{errs:?}");
}
