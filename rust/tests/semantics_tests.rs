//! Differential semantics: every workload must produce identical results
//! on (1) the sequential oracle over the implicit IR, (2) the explicit-IR
//! abstract machine, (3) the multithreaded WS runtime, and (4) the cycle
//! simulator — with and without DAE.

use bombyx::backend::emu;
use bombyx::interp::explicit_exec::{ExplicitExec, Order};
use bombyx::interp::{oracle, Memory, NoXla};
use bombyx::ir::{Module, Value};
use bombyx::lower::{compile, CompileOptions, CompileResult};
use bombyx::sim::{simulate, NoSimXla, SimConfig};
use bombyx::workloads::{bfs, fib, graphgen, nqueens, qsort};

/// Run one program on all four engines and check agreement of the result
/// value and of every global array image.
fn check_all_engines(
    r: &CompileResult,
    entry: &str,
    args: &[Value],
    init: impl Fn(&Module, &mut Memory),
) -> Value {
    // 1. Oracle.
    let mut mem = Memory::new(&r.implicit);
    init(&r.implicit, &mut mem);
    let (v_oracle, mem_oracle) =
        oracle::run_oracle(&r.implicit, mem, entry, args).expect("oracle");

    // 2. Explicit machine (both queue orders).
    for order in [Order::Lifo, Order::Fifo] {
        let mut mem = Memory::new(&r.explicit);
        init(&r.explicit, &mut mem);
        let mut ex = ExplicitExec::new(&r.explicit, mem, NoXla);
        ex.order = order;
        let v = ex.run(entry, args).expect("explicit");
        assert_eq!(norm(v), norm(v_oracle), "explicit {order:?}");
        assert_eq!(ex.live_closures(), 0, "closure leak ({order:?})");
        compare_memory(&r.implicit, &mem_oracle, &r.explicit, &ex.memory);
    }

    // 3. WS runtime.
    emu::check_equivalence(
        r,
        entry,
        args,
        |m, mem| {
            init(m, mem);
            Ok(())
        },
        4,
    )
    .expect("ws equivalence");

    // 4. Simulator.
    let mut mem = Memory::new(&r.explicit);
    init(&r.explicit, &mut mem);
    let (v_sim, mem_sim, _) =
        simulate(&r.explicit, mem, entry, args, &SimConfig::default(), &mut NoSimXla)
            .expect("sim");
    assert_eq!(norm(v_sim), norm(v_oracle), "sim");
    compare_memory(&r.implicit, &mem_oracle, &r.explicit, &mem_sim);

    v_oracle
}

fn norm(v: Value) -> i64 {
    v.as_i64()
}

fn compare_memory(ma: &Module, a: &Memory, mb: &Module, b: &Memory) {
    for (gid, g) in ma.globals.iter() {
        let other = mb.global_by_name(&g.name).expect("global preserved");
        assert_eq!(a.dump_i64(gid), b.dump_i64(other), "global `{}`", g.name);
    }
}

#[test]
fn fib_all_engines() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let v = check_all_engines(&r, "fib", &[Value::I64(16)], |_, _| {});
    assert_eq!(v.as_i64(), fib::fib_ref(16) as i64);
}

#[test]
fn bfs_all_engines_with_and_without_dae() {
    let g = graphgen::tree(3, 5);
    for (src, opts) in [
        (bfs::BFS_SRC, CompileOptions::no_dae()),
        (bfs::BFS_DAE_SRC, CompileOptions::standard()),
    ] {
        let r = compile("bfs", src, &opts).unwrap();
        check_all_engines(&r, "visit", &[Value::I64(0)], |m, mem| {
            bfs::init_memory(m, mem, &g).unwrap();
        });
    }
}

#[test]
fn nqueens_all_engines() {
    let r = compile("nq", nqueens::NQUEENS_SRC, &CompileOptions::no_dae()).unwrap();
    let args: Vec<Value> = [6i64, 0, 0, 0, 0].iter().map(|&v| Value::I64(v)).collect();
    check_all_engines(&r, "place", &args, |_, _| {});
}

#[test]
fn qsort_all_engines() {
    let r = compile("qs", qsort::QSORT_SRC, &CompileOptions::no_dae()).unwrap();
    let input: Vec<i64> = (0..64).map(|i| ((i * 37 + 11) % 100) - 50).collect();
    check_all_engines(
        &r,
        "qsort_",
        &[Value::I64(0), Value::I64(63)],
        |m, mem| {
            mem.fill_i64(m.global_by_name("data").unwrap(), &input);
        },
    );
}

#[test]
fn paper_tree_small_visits_everything_on_sim() {
    let g = graphgen::paper_tree_small();
    let r = compile("bfs", bfs::BFS_DAE_SRC, &CompileOptions::standard()).unwrap();
    let mut mem = Memory::new(&r.explicit);
    bfs::init_memory(&r.explicit, &mut mem, &g).unwrap();
    let (_, mem, stats) = simulate(
        &r.explicit,
        mem,
        "visit",
        &[Value::I64(0)],
        &SimConfig::paper(),
        &mut NoSimXla,
    )
    .unwrap();
    bfs::check_all_visited(&r.explicit, &mem, &g).unwrap();
    // 5,461 nodes → 5,461 visit tasks.
    assert_eq!(stats.task("visit").unwrap().executed, 5_461);
}
