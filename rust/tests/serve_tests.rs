//! Integration tests for the `bombyx serve` daemon: concurrent clients
//! over the unix-socket protocol, LRU eviction + cold re-admission,
//! per-request error isolation, clean shutdown with connection drain,
//! cross-source dedup, and telemetry (serve spans + `serve.*` metrics).
//!
//! Every test runs its own in-process [`Server`] on a unique socket
//! under the temp dir, so tests parallelize freely; only the telemetry
//! test touches the process-global obs state (serialized on
//! [`OBS_LOCK`], same discipline as `obs_tests.rs`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::thread;

use bombyx::ir::print::print_module;
use bombyx::lower::{CompileOptions, CompileSession};
use bombyx::obs;
use bombyx::serve::{Client, ServeConfig, Server};
use bombyx::util::json::{self, Json};
use bombyx::workloads::{bfs, fib, nqueens, qsort};

static OBS_LOCK: Mutex<()> = Mutex::new(());
static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// Unique socket path per test (unix socket paths are length-limited,
/// so keep it short and under the temp dir).
fn sock(tag: &str) -> PathBuf {
    let seq = SOCK_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("bx-{}-{seq}-{tag}.sock", std::process::id()))
}

fn start(tag: &str, tweak: impl FnOnce(&mut ServeConfig)) -> Server {
    let mut config = ServeConfig::new(sock(tag));
    tweak(&mut config);
    Server::start(config).expect("server starts")
}

fn is_ok(resp: &Json) -> bool {
    resp.get("ok") == Some(&Json::Bool(true))
}

/// The explicit IR a cold CLI compile of `source` would print, under the
/// same option resolution the daemon applies (DAE iff the source carries
/// the pragma — none of these tests pass `dae`/`no_dae` flags).
fn cold_ir(name: &str, source: &str) -> String {
    let opts = if source.contains("#pragma bombyx dae") {
        CompileOptions::standard()
    } else {
        CompileOptions::no_dae()
    };
    let session = CompileSession::new(name, source, &opts).expect("cold compile");
    print_module(session.explicit())
}

/// A structurally unique little program per tag (distinct function
/// names defeat both dedup tiers, forcing genuinely cold compiles).
fn leaf_src(tag: &str) -> String {
    format!("int f_{tag}(int n) {{ return n + {}; }}\n", tag.len())
}

// ---------------------------------------------------------------------------
// Concurrency
// ---------------------------------------------------------------------------

#[test]
fn concurrent_clients_compile_mixed_sources() {
    let server = start("conc", |_| {});
    let socket = server.socket().to_path_buf();
    let corpus: Vec<(&str, &str)> = vec![
        ("fib", fib::FIB_SRC),
        ("bfs_dae", bfs::BFS_DAE_SRC),
        ("nqueens", nqueens::NQUEENS_SRC),
        ("qsort", qsort::QSORT_SRC),
    ];
    let mut threads = Vec::new();
    for (name, src) in corpus {
        let socket = socket.clone();
        threads.push(thread::spawn(move || {
            let mut client = Client::connect(&socket).expect("connect");
            // Cold compile with IR echo: must match a cold CLI compile.
            let resp = client
                .compile_with(name, src, |m| {
                    m.set("echo", true);
                })
                .expect("compile");
            assert!(is_ok(&resp), "{name}: {}", resp.compact());
            assert_eq!(resp.get("warm"), Some(&Json::Bool(false)), "{name}");
            assert_eq!(
                resp.get("ir").and_then(Json::as_str),
                Some(cold_ir(name, src).as_str()),
                "{name}: daemon IR diverged from cold compile"
            );
            // A content-only touch routes to the warm session.
            let touched = format!("{src}\n// touched\n");
            let resp = client.recompile(name, &touched).expect("recompile");
            assert!(is_ok(&resp), "{name}: {}", resp.compact());
            assert_eq!(resp.get("warm"), Some(&Json::Bool(true)), "{name}");
            assert_eq!(resp.get("mode").and_then(Json::as_str), Some("unchanged"), "{name}");
        }));
    }
    for t in threads {
        t.join().expect("client thread");
    }
    let snap = server.stats();
    assert_eq!(snap.requests, 8);
    assert_eq!(snap.cache_hits, 4);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.sessions, 4);
    server.shutdown();
    server.join().expect("join");
}

#[test]
fn batch_shards_and_preserves_item_order() {
    let server = start("batch", |_| {});
    let mut client = Client::connect(server.socket()).expect("connect");
    let sources: Vec<(String, String)> =
        (0..6).map(|i| (format!("b{i}"), leaf_src(&format!("b{i}x{i}")))).collect();
    let items: Vec<(&str, &str)> =
        sources.iter().map(|(id, src)| (id.as_str(), src.as_str())).collect();
    let resp = client.batch(&items, 3).expect("batch");
    assert!(is_ok(&resp), "{}", resp.compact());
    let results = resp.get("results").and_then(Json::as_array).expect("results");
    assert_eq!(results.len(), 6);
    for (i, r) in results.iter().enumerate() {
        assert!(is_ok(r), "item {i}: {}", r.compact());
        assert_eq!(r.get("id").and_then(Json::as_str), Some(format!("b{i}").as_str()));
    }
    let snap = server.stats();
    assert_eq!(snap.compiles, 6);
    assert_eq!(snap.sessions, 6);
    server.shutdown();
    server.join().expect("join");
}

// ---------------------------------------------------------------------------
// LRU + dedup
// ---------------------------------------------------------------------------

#[test]
fn lru_evicts_and_readmits_cold_with_identical_output() {
    let server = start("lru", |c| c.capacity = 2);
    let mut client = Client::connect(server.socket()).expect("connect");
    let (a_src, b_src, c_src) = (leaf_src("alpha"), leaf_src("bravo"), leaf_src("charlie"));
    assert!(is_ok(&client.compile("a", &a_src).unwrap()));
    assert!(is_ok(&client.compile("b", &b_src).unwrap()));
    // Third insert overflows capacity 2: "a" is LRU and must go.
    let resp = client.compile("c", &c_src).unwrap();
    assert!(is_ok(&resp));
    assert_eq!(resp.get("evicted").and_then(Json::as_i64), Some(1));
    assert_eq!(server.stats().evictions, 1);

    // Re-admission of the evicted id is a cache miss that recompiles
    // cold (the resident donors are structurally unrelated), and the
    // result is byte-identical to a cold CLI compile.
    let resp = client
        .compile_with("a", &a_src, |m| {
            m.set("echo", true);
        })
        .unwrap();
    assert!(is_ok(&resp));
    assert_eq!(resp.get("warm"), Some(&Json::Bool(false)));
    assert_eq!(resp.get("mode").and_then(Json::as_str), Some("cold"));
    assert_eq!(
        resp.get("ir").and_then(Json::as_str),
        Some(cold_ir("a", &a_src).as_str()),
        "re-admitted compile diverged from cold"
    );
    // The re-admission evicted the next LRU ("b"); "c" stayed warm.
    let resp = client.recompile("c", &c_src).unwrap();
    assert!(is_ok(&resp));
    assert_eq!(resp.get("warm"), Some(&Json::Bool(true)));
    server.shutdown();
    server.join().expect("join");
}

#[test]
fn identical_template_sources_share_one_compilation() {
    let server = start("dedup", |_| {});
    let mut client = Client::connect(server.socket()).expect("connect");
    // A template workload: many ids, one source text.
    for i in 0..4 {
        let resp = client
            .compile_with(&format!("t{i}"), fib::FIB_SRC, |m| {
                m.set("echo", true);
            })
            .unwrap();
        assert!(is_ok(&resp), "t{i}: {}", resp.compact());
        let want_mode = if i == 0 { "cold" } else { "identical" };
        assert_eq!(resp.get("mode").and_then(Json::as_str), Some(want_mode), "t{i}");
        assert_eq!(
            resp.get("ir").and_then(Json::as_str),
            Some(cold_ir("t", fib::FIB_SRC).as_str()),
            "t{i}: shared compilation diverged from cold"
        );
    }
    let snap = server.stats();
    assert_eq!(snap.dedup_hits, 3, "identical-content misses must share the donor");
    assert_eq!(snap.sessions, 4);
    server.shutdown();
    server.join().expect("join");
}

// ---------------------------------------------------------------------------
// Error isolation + shutdown
// ---------------------------------------------------------------------------

#[test]
fn request_errors_are_isolated_per_request() {
    let server = start("iso", |_| {});
    let mut client = Client::connect(server.socket()).expect("connect");
    let good = leaf_src("iso");
    assert!(is_ok(&client.compile("iso", &good).unwrap()));

    // A bad edit reports an error but must not poison the warm session.
    let resp = client.recompile("iso", "int nope(").unwrap();
    assert!(!is_ok(&resp));
    assert!(resp.get("error").and_then(Json::as_str).is_some());
    let resp = client.recompile("iso", &good).unwrap();
    assert!(is_ok(&resp), "{}", resp.compact());
    assert_eq!(resp.get("warm"), Some(&Json::Bool(true)), "bad edit evicted the warm session");

    // A bad brand-new source fails without registering anything, and the
    // same connection keeps serving.
    let resp = client.compile("junk", "void broken {").unwrap();
    assert!(!is_ok(&resp));
    let resp = client.codegen("junk", "rtl", None).unwrap();
    assert!(!is_ok(&resp), "uncached id without source must error");
    assert!(is_ok(&client.stats().unwrap()));

    // An unknown codegen target errors but keeps the session resident.
    let resp = client.codegen("iso", "vhdl", None).unwrap();
    assert!(!is_ok(&resp));
    let resp = client.codegen("iso", "emu", None).unwrap();
    assert!(is_ok(&resp), "{}", resp.compact());

    // Four failed requests (bad edit, bad new source, codegen without a
    // source, unknown target) — and exactly one healthy session left.
    let snap = server.stats();
    assert_eq!(snap.errors, 4);
    assert_eq!(snap.sessions, 1);
    server.shutdown();
    server.join().expect("join");
}

#[test]
fn shutdown_drains_connections_and_removes_socket() {
    let server = start("down", |_| {});
    let socket = server.socket().to_path_buf();
    // A second, idle connection: its handler must drain on shutdown
    // rather than wedge `join`.
    let _idle = Client::connect(&socket).expect("connect idle");
    let mut client = Client::connect(&socket).expect("connect");
    assert!(is_ok(&client.compile("d", &leaf_src("down")).unwrap()));
    // The shutdown response itself arrives before the daemon stops.
    let resp = client.shutdown().expect("shutdown response");
    assert!(is_ok(&resp));
    let snap = server.join().expect("join");
    assert_eq!(snap.requests, 2);
    assert!(!socket.exists(), "socket file must be removed on shutdown");
    assert!(Client::connect(&socket).is_err(), "daemon must be gone");
}

// ---------------------------------------------------------------------------
// Telemetry
// ---------------------------------------------------------------------------

#[test]
fn requests_emit_serve_spans_and_metrics() {
    let _guard = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::reset_all();
    obs::set_trace(true);
    obs::set_metrics(true);

    let server = start("tele", |_| {});
    let mut client = Client::connect(server.socket()).expect("connect");
    assert!(is_ok(&client.compile("tele_probe", &leaf_src("tele")).unwrap()));
    assert!(is_ok(&client.stats().unwrap()));
    client.shutdown().expect("shutdown");
    server.join().expect("join");

    obs::set_trace(false);
    obs::set_metrics(false);

    // Request spans: every op opened a `serve`-category span named
    // `serve <op> <id>`; B/E must both be present for our probe.
    let events = obs::trace::drain();
    let probe: Vec<&str> = events
        .iter()
        .filter(|e| e.cat == "serve" && e.name.contains("tele_probe"))
        .map(|e| e.ph)
        .collect();
    assert_eq!(probe, vec!["B", "E"], "expected one balanced serve span for the probe request");
    assert!(
        events.iter().any(|e| e.cat == "serve" && e.name.contains("serve stats")),
        "stats op must get a serve span too"
    );

    // Metrics: counters and the request-latency histogram are in the
    // standard registry export. Parallel serve tests may add to the
    // totals while metrics are armed, so bound from below only.
    let doc = obs::metrics::export_json();
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(obs::metrics::SCHEMA));
    assert!(obs::metrics::counter("serve.requests") >= 3);
    assert!(obs::metrics::counter("serve.requests.compile") >= 1);
    assert!(obs::metrics::counter("serve.compiles") >= 1);
    let hists = doc.get("histograms").expect("histograms section");
    assert!(hists.get("serve.request_ms").is_some(), "{}", doc.pretty());
    assert!(hists.get("serve.compile_ms").is_some(), "{}", doc.pretty());
    obs::reset_all();
}

// ---------------------------------------------------------------------------
// CI artifact validation (no-op without the env vars)
// ---------------------------------------------------------------------------

/// The CI serve smoke step runs `serve_bench` with `BOMBYX_BENCH_SMOKE=1`
/// (which also arms obs and dumps trace/metrics artifacts), then points
/// the env vars below at the emitted files so this test schema-validates
/// them in a fresh process.
#[test]
fn ci_serve_artifacts_validate() {
    if let Ok(path) = std::env::var("BOMBYX_SERVE_BENCH_FILE") {
        let text = std::fs::read_to_string(&path).expect("read serve bench artifact");
        let doc = json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve"), "{path}");
        for field in [
            "cold_ms_p50",
            "warm_ms_p50",
            "warm_speedup",
            "serial_cps",
            "batch_cps",
            "batch_speedup",
            "dedup_hits",
            "requests",
        ] {
            assert!(doc.get(field).is_some(), "{path}: missing `{field}`");
        }
        assert!(
            doc.get("dedup_hits").and_then(Json::as_i64).unwrap_or(0) > 0,
            "{path}: template workload recorded no dedup hits"
        );
    }
    if let Ok(path) = std::env::var("BOMBYX_SERVE_METRICS_FILE") {
        let text = std::fs::read_to_string(&path).expect("read serve metrics artifact");
        let doc = json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(obs::metrics::SCHEMA),
            "{path}: wrong schema tag"
        );
        let counters = doc.get("counters").expect("counters section");
        assert!(
            counters.get("serve.requests").and_then(Json::as_i64).unwrap_or(0) > 0,
            "{path}: no serve.requests counted"
        );
    }
    if let Ok(path) = std::env::var("BOMBYX_SERVE_TRACE_FILE") {
        let text = std::fs::read_to_string(&path).expect("read serve trace artifact");
        let doc = json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        let rows = doc
            .get("traceEvents")
            .and_then(Json::as_array)
            .unwrap_or_else(|| panic!("{path}: missing traceEvents"));
        assert!(
            rows.iter().any(|e| e.get("cat").and_then(Json::as_str) == Some("serve")),
            "{path}: no serve-category request spans in the smoke trace"
        );
    }
}
