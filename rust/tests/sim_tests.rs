//! Simulator integration: paper experiment bands, utilization accounting
//! and configuration sensitivities.

use bombyx::coordinator::run_bfs_comparison;
use bombyx::interp::Memory;
use bombyx::ir::Value;
use bombyx::lower::{compile, CompileOptions};
use bombyx::sim::{simulate, NoSimXla, SimConfig};
use bombyx::workloads::{bfs, fib, graphgen};

#[test]
fn paper_headline_band_d7() {
    let cmp = run_bfs_comparison(&graphgen::paper_tree_small(), &SimConfig::paper()).unwrap();
    let reduction = cmp.reduction();
    assert!(
        (0.20..0.33).contains(&reduction),
        "D=7 reduction {:.1}% outside the calibrated band (paper: 26.5%)",
        reduction * 100.0
    );
}

#[test]
fn utilization_is_sane() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let mem = Memory::new(&r.explicit);
    let (_, _, stats) = simulate(
        &r.explicit,
        mem,
        "fib",
        &[Value::I64(13)],
        &SimConfig::default(),
        &mut NoSimXla,
    )
    .unwrap();
    for (name, t) in &stats.per_task {
        assert!(
            (0.0..=1.0).contains(&t.utilization),
            "{name}: utilization {}",
            t.utilization
        );
    }
    // With 1 PE per type and a recursive workload, the entry PE dominates.
    let fib_util = stats.task("fib").unwrap().utilization;
    assert!(fib_util > 0.5, "fib PE should be the bottleneck: {fib_util}");
}

#[test]
fn memory_stats_accumulate() {
    let g = graphgen::tree(4, 4);
    let r = compile("bfs", bfs::BFS_SRC, &CompileOptions::no_dae()).unwrap();
    let mut mem = Memory::new(&r.explicit);
    bfs::init_memory(&r.explicit, &mut mem, &g).unwrap();
    let (_, _, stats) = simulate(
        &r.explicit,
        mem,
        "visit",
        &[Value::I64(0)],
        &SimConfig::paper(),
        &mut NoSimXla,
    )
    .unwrap();
    // Each node: 2 adj_off loads + per-edge loads.
    let expected = 2 * g.nodes() as u64 + g.edges() as u64;
    assert_eq!(stats.mem.requests, expected);
}

#[test]
fn dispatch_latency_slows_everything() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let run = |dispatch: u32| {
        let mut cfg = SimConfig::default();
        cfg.dispatch_latency = dispatch;
        let mem = Memory::new(&r.explicit);
        simulate(&r.explicit, mem, "fib", &[Value::I64(12)], &cfg, &mut NoSimXla)
            .unwrap()
            .2
            .cycles
    };
    // Dispatch latency only creates pipeline bubbles; with one PE fully
    // busy it should not dominate, but more must never be faster.
    assert!(run(40) >= run(4));
}

#[test]
fn zero_sized_problem_terminates() {
    let r = compile(
        "t",
        "void f(int n) { if (n > 0) { cilk_spawn f(n - 1); } cilk_sync; }",
        &CompileOptions::no_dae(),
    )
    .unwrap();
    let mem = Memory::new(&r.explicit);
    let (v, _, stats) =
        simulate(&r.explicit, mem, "f", &[Value::I64(0)], &SimConfig::default(), &mut NoSimXla)
            .unwrap();
    assert_eq!(v, Value::Unit);
    assert!(stats.cycles < 1000);
}

#[test]
fn deeper_tree_scales_roughly_linearly() {
    let cfg = SimConfig::paper();
    let small = run_bfs_comparison(&graphgen::tree(4, 5), &cfg).unwrap();
    let large = run_bfs_comparison(&graphgen::tree(4, 6), &cfg).unwrap();
    // 4x the nodes → between 3x and 5x the cycles (throughput-bound).
    let ratio = large.plain_cycles as f64 / small.plain_cycles as f64;
    assert!((3.0..5.0).contains(&ratio), "non-DAE scaling ratio {ratio}");
}
