//! WS-runtime integration: stress, scaling sanity, and failure injection.

use bombyx::ir::Value;
use bombyx::lower::{compile, CompileOptions};
use bombyx::workloads::{fib, nqueens};
use bombyx::ws::{self, NoXlaSink, ScalarSink, SharedMemory, WsConfig, XlaSink};

#[test]
fn stress_fib22_across_worker_counts() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    for workers in [1, 3, 8, 16] {
        let cfg = WsConfig { workers, steal_tries: 2 };
        let mem = SharedMemory::new(&r.explicit);
        let (v, _, stats) =
            ws::run(&r.explicit, mem, "fib", &[Value::I64(22)], &cfg, Box::new(NoXlaSink))
                .unwrap();
        assert_eq!(v.as_i64(), fib::fib_ref(22) as i64, "workers={workers}");
        assert!(stats.tasks_run > 50_000);
    }
}

#[test]
fn nqueens_8_parallel() {
    let r = compile("nq", nqueens::NQUEENS_SRC, &CompileOptions::no_dae()).unwrap();
    let args: Vec<Value> = [8i64, 0, 0, 0, 0].iter().map(|&v| Value::I64(v)).collect();
    let cfg = WsConfig { workers: 8, steal_tries: 4 };
    let mem = SharedMemory::new(&r.explicit);
    let (_, mem, _) = ws::run(&r.explicit, mem, "place", &args, &cfg, Box::new(NoXlaSink)).unwrap();
    assert_eq!(
        mem.dump_i64(r.explicit.global_by_name("solutions").unwrap())[0] as u64,
        nqueens::nqueens_ref(8)
    );
}

#[test]
fn repeated_runs_are_stable() {
    // 20 consecutive runs shake out races in the closure protocol.
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let cfg = WsConfig { workers: 8, steal_tries: 4 };
    for i in 0..20 {
        let mem = SharedMemory::new(&r.explicit);
        let (v, _, _) =
            ws::run(&r.explicit, mem, "fib", &[Value::I64(15)], &cfg, Box::new(NoXlaSink))
                .unwrap();
        assert_eq!(v.as_i64(), 610, "iteration {i}");
    }
}

#[test]
fn failure_injection_xla_sink_error_propagates() {
    let src = "extern xla int relax(int n);
        int f(int n) { int r = cilk_spawn relax(n); cilk_sync; return r; }";
    let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
    let sink = ScalarSink(|_: &str, _: &[Value], _: &SharedMemory| {
        anyhow::bail!("injected datapath failure")
    });
    let cfg = WsConfig { workers: 4, steal_tries: 4 };
    let mem = SharedMemory::new(&r.explicit);
    let err =
        ws::run(&r.explicit, mem, "f", &[Value::I64(1)], &cfg, Box::new(sink)).unwrap_err();
    assert!(err.to_string().contains("injected"), "{err}");
}

#[test]
fn failure_injection_wrong_result_arity() {
    struct BadSink;
    impl XlaSink for BadSink {
        fn exec_batch(
            &self,
            _n: &str,
            _b: &[Vec<Value>],
            _m: &SharedMemory,
        ) -> anyhow::Result<Vec<Value>> {
            Ok(vec![]) // wrong arity
        }
    }
    let src = "extern xla int relax(int n);
        int f(int n) { int r = cilk_spawn relax(n); cilk_sync; return r; }";
    let r = compile("t", src, &CompileOptions::no_dae()).unwrap();
    let cfg = WsConfig { workers: 2, steal_tries: 4 };
    let mem = SharedMemory::new(&r.explicit);
    let err =
        ws::run(&r.explicit, mem, "f", &[Value::I64(1)], &cfg, Box::new(BadSink)).unwrap_err();
    assert!(err.to_string().contains("results"), "{err}");
}

#[test]
fn unknown_entry_task_is_an_error() {
    let r = compile("fib", fib::FIB_SRC, &CompileOptions::no_dae()).unwrap();
    let mem = SharedMemory::new(&r.explicit);
    let err = ws::run(
        &r.explicit,
        mem,
        "nonexistent",
        &[],
        &WsConfig { workers: 2, steal_tries: 2 },
        Box::new(NoXlaSink),
    )
    .unwrap_err();
    assert!(err.to_string().contains("no task named"));
}
