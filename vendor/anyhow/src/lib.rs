//! Minimal offline drop-in for the `anyhow` error crate.
//!
//! The build environment has no network access and no vendored registry, so
//! the subset of `anyhow`'s API that this repository actually uses is
//! implemented here as a path dependency: [`Error`], [`Result`],
//! [`anyhow!`], [`bail!`], and the [`Context`] extension trait.
//!
//! Error chains are flattened into the message eagerly at construction, so
//! `{e}` and `{e:#}` render the same full `top: cause: cause` string — the
//! callers in this repository only ever match on substrings of that text.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error` it deliberately does
/// NOT implement `std::error::Error`, which is what allows the blanket
/// `From<E: std::error::Error>` conversion below to coexist with the
/// identity `From<Error>` used by `?`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — plain `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (`context: original` message layout, matching
/// upstream anyhow's rendering of a one-deep chain).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string, a formattable value, or an
/// existing error.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{:#}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
        assert_eq!(format!("{e:?}"), "boom 42");
    }

    #[test]
    fn captures_in_literals() {
        let x = 7;
        let e = anyhow!("value {x}");
        assert_eq!(e.to_string(), "value 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn bail_with_error_value() {
        fn f() -> Result<()> {
            let err = anyhow!("original");
            bail!(err)
        }
        assert_eq!(f().unwrap_err().to_string(), "original");
    }
}
