//! Compile-only stub of the `xla` PJRT binding.
//!
//! The real binding wraps the PJRT CPU client and is only exercised by the
//! artifact-gated paths (`bombyx::runtime`, `make artifacts`); every test,
//! bench and example that needs it probes `XlaRuntime::load_dir` first and
//! skips when it fails. This stub keeps those paths *compiling* in an
//! offline environment: every runtime entry point returns an error, so the
//! probes fail cleanly and the gated code is never reached.
//!
//! The API surface (and only that surface) matches what
//! `bombyx::runtime::{client, relax}` uses. Swap this crate for the real
//! binding in `Cargo.toml` to enable the XLA datapath.

use std::fmt;

/// Stub error: every fallible entry point returns this.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error("XLA PJRT runtime is not available in this build (vendor/xla stub)".to_string())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a `Literal` can hold / be read back as.
pub trait ArrayElement {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: ArrayElement>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }
}
